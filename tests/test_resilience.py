"""Resilient I/O layer (DESIGN.md §17): retry policy + error taxonomy,
circuit breaker state machine, the ResilientStore wrapper (retries,
checksums, hedged reads, breaker gating), the ChaosStore harness, tiered
circuit-broken failover, quarantine auto-retry, and the bounded close
path.

Every ChaosStore schedule here is seeded or scripted (``fail_next`` /
``kill``), so failures replay deterministically; nothing in this file
depends on wall-clock beyond short breaker reset windows.
"""

import errno
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (
    BreakerOpenError,
    ChaosStore,
    CircuitBreaker,
    CorruptPageError,
    HostArrayStore,
    ResilientStore,
    RetryPolicy,
    TieredStore,
    UMapConfig,
    umap,
    uunmap,
)
from repro.core.resilient import default_classify, iter_breakers, wrap_store

PAGE = 4096
EXTENT = 4 * PAGE
NPAGES = 64


def _data(nbytes: int) -> np.ndarray:
    return (np.arange(nbytes, dtype=np.int64) % 251).astype(np.uint8)


def _mem(nbytes: int, pattern: bool = True) -> HostArrayStore:
    return HostArrayStore(_data(nbytes) if pattern
                          else np.zeros(nbytes, np.uint8))


def _fast_policy(**kw) -> RetryPolicy:
    kw.setdefault("retries", 3)
    kw.setdefault("backoff_s", 1e-4)
    kw.setdefault("max_backoff_s", 1e-3)
    return RetryPolicy(**kw)


# ------------------------------------------------------- error taxonomy


class TestClassify:
    def test_transient_errors(self):
        assert default_classify(OSError(errno.EIO, "io"))
        assert default_classify(OSError(errno.EAGAIN, "again"))
        assert default_classify(OSError("no errno at all"))
        assert default_classify(TimeoutError("slow"))
        assert default_classify(CorruptPageError("crc"))
        assert default_classify(BreakerOpenError("open"))

    def test_permanent_errors(self):
        assert not default_classify(ValueError("bad arg"))
        assert not default_classify(TypeError("bad type"))
        assert not default_classify(KeyError("k"))
        assert not default_classify(NotImplementedError())
        assert not default_classify(PermissionError("denied"))
        assert not default_classify(FileNotFoundError("gone"))
        for eno in (errno.EACCES, errno.ENOENT, errno.ENOSPC, errno.EROFS):
            assert not default_classify(OSError(eno, "permanent"))

    def test_backoff_grows_and_caps(self):
        import random
        pol = RetryPolicy(backoff_s=0.01, max_backoff_s=0.04, jitter=0.0)
        rng = random.Random(0)
        sleeps = [pol.sleep_s(a, rng) for a in range(5)]
        assert sleeps[0] == pytest.approx(0.01)
        assert sleeps[1] == pytest.approx(0.02)
        assert sleeps[2] == pytest.approx(0.04)
        assert sleeps[4] == pytest.approx(0.04)      # capped

    def test_jitter_bounded(self):
        import random
        pol = RetryPolicy(backoff_s=0.01, max_backoff_s=0.01, jitter=0.5)
        rng = random.Random(7)
        for a in range(20):
            s = pol.sleep_s(a, rng)
            assert 0.01 <= s <= 0.015


# ------------------------------------------------------- circuit breaker


class TestCircuitBreaker:
    def test_threshold_trips_open(self):
        br = CircuitBreaker(threshold=3, reset_s=60.0)
        for _ in range(2):
            assert br.allow()
            br.record_failure()
        assert br.state == "closed"
        assert br.allow()
        br.record_failure()
        assert br.state == "open"
        assert br.tripped()
        assert not br.allow()

    def test_success_resets_failure_streak(self):
        br = CircuitBreaker(threshold=2, reset_s=60.0)
        br.record_failure()
        br.record_success()
        br.record_failure()
        assert br.state == "closed"      # streak broken: 1+1, not 2

    def test_half_open_probe_cycle_closes(self):
        clock = [0.0]
        br = CircuitBreaker(threshold=1, reset_s=1.0, probes=2,
                            clock=lambda: clock[0])
        br.record_failure()
        assert br.state == "open" and not br.allow()
        clock[0] = 1.5
        assert not br.tripped()          # reset elapsed: route traffic again
        assert br.allow()                # probe 1 admitted, half-opens
        assert br.state == "half_open"
        br.record_success()
        assert br.allow()
        br.record_success()
        assert br.state == "closed"
        assert br.stats()["breaker_closes"] == 1

    def test_probe_failure_reopens(self):
        clock = [0.0]
        br = CircuitBreaker(threshold=1, reset_s=1.0, probes=2,
                            clock=lambda: clock[0])
        br.record_failure()
        clock[0] = 1.5
        assert br.allow()
        br.record_failure()
        assert br.state == "open"
        assert br.stats()["breaker_opens"] == 2

    def test_half_open_bounds_concurrent_probes(self):
        clock = [0.0]
        br = CircuitBreaker(threshold=1, reset_s=1.0, probes=2,
                            clock=lambda: clock[0])
        br.record_failure()
        clock[0] = 2.0
        assert br.allow() and br.allow()     # two probe slots
        assert not br.allow()                # third rejected
        br.record_success()
        assert br.allow()                    # slot released

    def test_listeners_see_every_edge(self):
        clock = [0.0]
        br = CircuitBreaker(threshold=1, reset_s=1.0, probes=1,
                            clock=lambda: clock[0])
        edges = []
        br.add_listener(lambda old, new: edges.append((old, new)))
        br.record_failure()
        clock[0] = 1.5
        br.allow()
        br.record_success()
        assert edges == [("closed", "open"), ("open", "half_open"),
                         ("half_open", "closed")]
        br.remove_listener(edges.append)     # unknown fn: no-op

    def test_listener_exception_swallowed(self):
        br = CircuitBreaker(threshold=1)

        def bomb(old, new):
            raise RuntimeError("listener bug")

        br.add_listener(bomb)
        br.record_failure()                  # must not raise
        assert br.state == "open"

    def test_open_seconds_accumulates(self):
        clock = [0.0]
        br = CircuitBreaker(threshold=1, reset_s=10.0, probes=1,
                            clock=lambda: clock[0])
        br.record_failure()                  # opens at t=0
        clock[0] = 4.0
        assert br.open_seconds() == pytest.approx(4.0)
        clock[0] = 12.0
        br.allow()                           # half-open at t=12
        br.record_success()                  # closed
        assert br.open_seconds() == pytest.approx(12.0)
        clock[0] = 20.0
        assert br.open_seconds() == pytest.approx(12.0)   # stopped counting


# ------------------------------------------------------- resilient store


class TestResilientStore:
    def test_passthrough_and_stats_shape(self):
        rs = ResilientStore(_mem(8 * PAGE), policy=_fast_policy())
        buf = np.empty(PAGE, np.uint8)
        assert rs.read_into(0, buf) == PAGE
        assert np.array_equal(buf, _data(PAGE))
        snap = rs.resilience_stats()
        for key in ("retries", "retries_ok", "exhausted", "permanent_errors",
                    "breaker_rejections", "hedges", "hedge_wins",
                    "checksum_failures", "deadline_exceeded", "breaker_state",
                    "breaker_opens", "degraded_seconds"):
            assert key in snap, key
        assert snap["retries"] == 0 and snap["breaker_state"] == 0

    def test_transient_errors_absorbed_by_retry(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.fail_next("read", count=2)
        rs = ResilientStore(chaos, policy=_fast_policy())
        buf = np.empty(PAGE, np.uint8)
        assert rs.read_into(0, buf) == PAGE
        assert np.array_equal(buf, _data(PAGE))
        snap = rs.resilience_stats()
        assert snap["retries"] == 2 and snap["retries_ok"] == 1
        assert snap["exhausted"] == 0

    def test_retry_budget_exhausted_raises(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.fail_next("read", count=10)
        rs = ResilientStore(chaos, policy=_fast_policy(retries=2))
        with pytest.raises(OSError):
            rs.read_into(0, np.empty(PAGE, np.uint8))
        snap = rs.resilience_stats()
        assert snap["exhausted"] == 1 and snap["retries"] == 2

    def test_permanent_error_never_retried(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.fail_next("read", count=1, permanent=True)
        rs = ResilientStore(chaos, policy=_fast_policy())
        with pytest.raises(PermissionError):
            rs.read_into(0, np.empty(PAGE, np.uint8))
        snap = rs.resilience_stats()
        assert snap["permanent_errors"] == 1 and snap["retries"] == 0
        assert chaos.chaos_stats()["reads_attempted"] == 1

    def test_deadline_bounds_total_backoff(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.fail_next("read", count=100)
        rs = ResilientStore(chaos, policy=RetryPolicy(
            retries=100, backoff_s=0.05, max_backoff_s=0.05,
            deadline_s=0.12))
        t0 = time.monotonic()
        with pytest.raises(OSError):
            rs.read_into(0, np.empty(PAGE, np.uint8))
        assert time.monotonic() - t0 < 1.0
        snap = rs.resilience_stats()
        assert snap["deadline_exceeded"] == 1 and snap["exhausted"] == 1

    def test_breaker_trips_then_fails_fast(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.kill()
        rs = ResilientStore(chaos, policy=_fast_policy(retries=0),
                            breaker=CircuitBreaker(threshold=2, reset_s=60.0))
        buf = np.empty(PAGE, np.uint8)
        for _ in range(2):
            with pytest.raises(OSError):
                rs.read_into(0, buf)
        attempted = chaos.chaos_stats()["reads_attempted"]
        with pytest.raises(BreakerOpenError):
            rs.read_into(0, buf)
        # fail-fast: the dead store was NOT touched again
        assert chaos.chaos_stats()["reads_attempted"] == attempted
        assert rs.resilience_stats()["breaker_rejections"] == 1

    def test_breaker_recovery_closes_after_probes(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=1)
        chaos.kill()
        rs = ResilientStore(chaos, policy=_fast_policy(retries=0),
                            breaker=CircuitBreaker(threshold=1, reset_s=0.05,
                                                   probes=2))
        buf = np.empty(PAGE, np.uint8)
        with pytest.raises(OSError):
            rs.read_into(0, buf)
        assert rs.breaker.state == "open"
        chaos.revive()
        time.sleep(0.06)
        rs.read_into(0, buf)
        rs.read_into(0, buf)
        assert rs.breaker.state == "closed"
        assert np.array_equal(buf, _data(PAGE))

    def test_checksum_catches_bit_flip(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=5)
        rs = ResilientStore(chaos, policy=_fast_policy(),
                            verify_reads=True, checksum_block=PAGE)
        buf = np.empty(PAGE, np.uint8)
        rs.read_into(0, buf)                         # records the block CRC
        chaos.bit_flip_rate = 1.0                    # every read now corrupts
        with pytest.raises(OSError):                 # retries all corrupt too
            rs.read_into(0, np.empty(PAGE, np.uint8))
        snap = rs.resilience_stats()
        assert snap["checksum_failures"] >= 1

    def test_checksum_retry_recovers_one_shot_corruption(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=5, bit_flip_rate=0.0)
        rs = ResilientStore(chaos, policy=_fast_policy(),
                            verify_reads=True, checksum_block=PAGE)
        good = np.empty(PAGE, np.uint8)
        rs.read_into(0, good)
        # corrupt exactly one read, then heal: the retry must re-read clean
        chaos.bit_flip_rate = 1.0
        orig = chaos._maybe_flip

        def flip_once(bufs):
            orig(bufs)
            chaos.bit_flip_rate = 0.0    # heal after this one corruption

        chaos._maybe_flip = flip_once
        buf = np.empty(PAGE, np.uint8)
        rs.read_into(0, buf)
        assert np.array_equal(buf, _data(PAGE))
        snap = rs.resilience_stats()
        assert snap["checksum_failures"] == 1 and snap["retries_ok"] == 1

    def test_checksum_written_blocks_verified(self):
        rs = ResilientStore(_mem(8 * PAGE, pattern=False),
                            policy=_fast_policy(), verify_reads=True,
                            checksum_block=PAGE)
        payload = np.full(PAGE, 7, np.uint8)
        rs.write_from(PAGE, payload)
        # corrupt the inner store directly behind the wrapper's back
        rs.inner._data[PAGE + 100] ^= 0xFF
        with pytest.raises(OSError):
            rs.read_into(PAGE, np.empty(PAGE, np.uint8))
        assert rs.resilience_stats()["checksum_failures"] >= 1

    def test_partial_write_invalidates_block_crc(self):
        rs = ResilientStore(_mem(8 * PAGE, pattern=False),
                            policy=_fast_policy(), verify_reads=True,
                            checksum_block=PAGE)
        rs.write_from(0, np.full(PAGE, 1, np.uint8))
        rs.write_from(100, np.full(8, 2, np.uint8))      # partial: CRC dropped
        buf = np.empty(PAGE, np.uint8)
        rs.read_into(0, buf)                             # re-records, no raise
        assert buf[100] == 2 and buf[0] == 1

    def test_hedged_read_waits_out_latency_spike(self):
        # Primary read stalls 0.5s inside the store; the spike clears at
        # 30ms, the hedge fires at 80ms against the healed store and wins
        # long before the stuck primary returns.
        chaos = ChaosStore(_mem(8 * PAGE), seed=2,
                           latency_spike_rate=1.0, latency_spike_s=0.5)
        rs = ResilientStore(chaos, policy=_fast_policy(),
                            hedge_delay_s=0.08, name="hedge-test")

        def heal():
            time.sleep(0.03)
            chaos.latency_spike_rate = 0.0

        t = threading.Thread(target=heal)
        t.start()
        buf = np.empty(PAGE, np.uint8)
        t0 = time.monotonic()
        rs.read_into(0, buf)
        dt = time.monotonic() - t0
        t.join()
        rs.close()
        assert np.array_equal(buf, _data(PAGE))
        snap = rs.resilience_stats()
        assert snap["hedges"] >= 1 and snap["hedge_wins"] >= 1
        assert dt < 0.4, "hedge should beat the spiked primary"

    def test_batch_ops_route_through_wrapper(self):
        chaos = ChaosStore(_mem(8 * PAGE), seed=3)
        chaos.fail_next("write", count=1)
        rs = ResilientStore(chaos, policy=_fast_policy())
        bufs = [np.full(PAGE, 9, np.uint8) for _ in range(2)]
        assert rs.write_from_batch(0, bufs) == 2 * PAGE
        assert rs.resilience_stats()["retries_ok"] == 1
        out = [np.empty(PAGE, np.uint8) for _ in range(2)]
        assert rs.read_into_batch(0, out) == 2 * PAGE
        assert all((o == 9).all() for o in out)

    def test_wrap_store_idempotent_and_tier_aware(self):
        cfg = UMapConfig(resilient_io=True)
        flat = wrap_store(_mem(8 * PAGE), cfg)
        assert isinstance(flat, ResilientStore)
        assert wrap_store(flat, cfg) is flat
        ts = TieredStore(_mem(2 * EXTENT, pattern=False), _mem(8 * EXTENT),
                         extent_size=EXTENT)
        wrapped = wrap_store(ts, cfg)
        assert wrapped is ts                      # identity preserved
        assert isinstance(ts.fast, ResilientStore)
        assert isinstance(ts.slow, ResilientStore)
        assert len(list(iter_breakers(ts))) == 2
        wrap_store(ts, cfg)                       # second wrap: no double-wrap
        assert not isinstance(ts.fast.inner, ResilientStore)


# ------------------------------------------------------------ chaos store


class TestChaosStore:
    def test_seeded_schedule_replays(self):
        def run(seed):
            ch = ChaosStore(_mem(32 * PAGE), seed=seed, read_error_rate=0.3)
            outcomes = []
            for i in range(50):
                try:
                    ch.read_into(i % 8 * PAGE, np.empty(PAGE, np.uint8))
                    outcomes.append("ok")
                except OSError:
                    outcomes.append("err")
            return outcomes

        assert run(42) == run(42)
        assert run(42) != run(43)       # astronomically unlikely to collide

    def test_kill_revive(self):
        ch = ChaosStore(_mem(8 * PAGE), seed=0)
        buf = np.empty(PAGE, np.uint8)
        ch.read_into(0, buf)
        ch.kill()
        assert ch.dead
        with pytest.raises(OSError):
            ch.read_into(0, buf)
        with pytest.raises(OSError):
            ch.write_from(0, buf)
        ch.revive()
        ch.read_into(0, buf)
        assert ch.chaos_stats()["outage_rejections"] == 2

    def test_torn_write_persists_prefix_then_raises(self):
        inner = _mem(8 * PAGE, pattern=False)
        ch = ChaosStore(inner, seed=9, torn_write_rate=1.0)
        with pytest.raises(OSError):
            ch.write_from(0, np.full(2 * PAGE, 5, np.uint8))
        st = ch.chaos_stats()
        assert st["torn_writes"] == 1
        written = int((inner._data[:2 * PAGE] == 5).sum())
        assert 0 <= written < 2 * PAGE            # strict prefix, never all

    def test_bit_flip_corrupts_exactly_one_bit(self):
        ch = ChaosStore(_mem(8 * PAGE), seed=4, bit_flip_rate=1.0)
        buf = np.empty(PAGE, np.uint8)
        ch.read_into(0, buf)
        diff = buf ^ _data(PAGE)
        assert int(np.unpackbits(diff).sum()) == 1
        assert ch.chaos_stats()["bit_flips"] == 1

    def test_fail_next_is_exact(self):
        ch = ChaosStore(_mem(8 * PAGE), seed=0)
        ch.fail_next("read", count=2)
        buf = np.empty(PAGE, np.uint8)
        for _ in range(2):
            with pytest.raises(OSError):
                ch.read_into(0, buf)
        ch.read_into(0, buf)                      # third op clean
        ch.write_from(0, buf)                     # writes unaffected
        st = ch.chaos_stats()
        assert st["injected_read_errors"] == 2
        assert st["injected_write_errors"] == 0

    def test_latency_spike_sleeps(self):
        ch = ChaosStore(_mem(8 * PAGE), seed=0, latency_spike_rate=1.0,
                        latency_spike_s=0.05)
        t0 = time.monotonic()
        ch.read_into(0, np.empty(PAGE, np.uint8))
        assert time.monotonic() - t0 >= 0.05
        assert ch.chaos_stats()["latency_spikes"] == 1


# ------------------------------------------------- tiered failover (§17.5)


def _tiered_chaos(fast_extents: int = 8, **chaos_kw):
    slow = _mem(NPAGES * PAGE)
    chaos = ChaosStore(_mem(fast_extents * EXTENT, pattern=False),
                       seed=13, **chaos_kw)
    ts = TieredStore(chaos, slow, extent_size=EXTENT, promote_on_read=True)
    cfg = UMapConfig(page_size=PAGE, buffer_size=16 * PAGE,
                     resilient_io=True, io_retries=4,
                     retry_backoff_s=0.002, retry_max_backoff_s=0.02,
                     breaker_threshold=3, breaker_reset_s=0.25)
    region = umap(ts, config=cfg)
    return region, ts, chaos


class TestTieredFailover:
    def test_fast_outage_degrades_to_slow_byte_exact(self):
        region, ts, chaos = _tiered_chaos()
        try:
            ref = _data(NPAGES * PAGE)
            for p in range(16):
                assert np.array_equal(region.read(p * PAGE, PAGE),
                                      ref[p * PAGE:(p + 1) * PAGE])
            assert ts.resident_extents()          # warm promoted something
            chaos.kill()
            # every read during the outage: correct bytes, zero exceptions
            for p in range(32):
                assert np.array_equal(region.read(p * PAGE, PAGE),
                                      ref[p * PAGE:(p + 1) * PAGE]), p
            assert ts.tier_failovers > 0
            assert region.service.open_breakers() == 1
            assert region.service.stats.io_errors == 0
        finally:
            chaos.revive()
            uunmap(region)

    def test_promotion_refused_while_tripped_resumes_after(self):
        region, ts, chaos = _tiered_chaos()
        try:
            region.read(0, PAGE)                  # warm
            chaos.kill()
            for p in range(16):
                region.read(p * PAGE, PAGE)
            assert ts.promote(5) is False         # tripped: no admissions
            chaos.revive()
            time.sleep(0.3)                       # reset window elapses
            for _ in range(3):
                for p in range(16):
                    region.read(p * PAGE, PAGE)
            assert ts.fast.breaker.state == "closed"
            assert ts.resident_extents()          # re-admitted
        finally:
            uunmap(region)

    def test_dirty_resident_bytes_survive_outage(self):
        """Dirty fast-tier extents hold the ONLY copy: routing must keep
        pointing at fast (errors propagate -> quarantine) rather than
        silently serving stale slow-tier bytes."""
        region, ts, chaos = _tiered_chaos()
        try:
            region.read(0, PAGE)
            assert ts.promote(0) or 0 in dict.fromkeys(ts.resident_extents())
            # dirty extent 0 via direct store write (bypasses pager cache)
            ts.write_from(0, np.full(PAGE, 77, np.uint8))
            assert ts.tier_stats()["dirty_extents"] >= 1
            chaos.kill()
            # a direct read of the dirty extent must NOT serve slow bytes
            with pytest.raises(OSError):
                ts.read_into(0, np.empty(PAGE, np.uint8))
            chaos.revive()
            time.sleep(0.3)
            buf = np.empty(PAGE, np.uint8)
            ts.read_into(0, buf)
            assert (buf == 77).all()              # the one true copy survived
        finally:
            uunmap(region)


# ------------------------------------------- quarantine auto-retry (§17.4)


class TestQuarantineRetry:
    def _quarantined_region(self):
        inner = _mem(32 * PAGE)
        chaos = ChaosStore(inner, seed=7)
        cfg = UMapConfig(page_size=PAGE, buffer_size=8 * PAGE,
                         resilient_io=True, io_retries=1,
                         retry_backoff_s=0.001, retry_deadline_s=0.2,
                         breaker_threshold=2, breaker_reset_s=0.2,
                         writeback_retries=1)
        region = umap(chaos, config=cfg)
        for p in range(4):
            region.write(p * PAGE, np.full(PAGE, 42, np.uint8))
        chaos.kill()
        with pytest.raises(IOError):
            region.service.flush_region(region)
        assert region.service.stats.quarantined_pages == 4
        return region, chaos, inner

    def test_manual_retry_quarantined(self):
        region, chaos, inner = self._quarantined_region()
        svc = region.service
        try:
            chaos.revive()
            time.sleep(0.25)                      # breaker reset window
            n = svc.retry_quarantined(region)
            assert n == 4
            deadline = time.time() + 3
            while time.time() < deadline and svc.stats.quarantined_pages:
                time.sleep(0.02)
            s = svc.stats
            assert s.quarantined_pages == 0
            assert s.quarantine_retries == 4
            svc.flush_region(region)
            chk = np.empty(PAGE, np.uint8)
            inner.read_into(0, chk)
            assert (chk == 42).all()              # zero lost pages
        finally:
            uunmap(region)

    def test_retry_while_store_still_dead_requarantines(self):
        region, chaos, _ = self._quarantined_region()
        svc = region.service
        try:
            assert svc.retry_quarantined(region) == 4
            deadline = time.time() + 3
            while time.time() < deadline and svc.stats.quarantined_pages < 4:
                time.sleep(0.02)
            assert svc.stats.quarantined_pages == 4   # failed again: back in
            assert svc.stats.quarantine_retries == 4
        finally:
            chaos.revive()
            time.sleep(0.25)                      # let the breaker half-open
            svc.retry_quarantined(region)
            deadline = time.time() + 3
            while time.time() < deadline and svc.stats.quarantined_pages:
                time.sleep(0.02)
            uunmap(region)

    def test_breaker_close_auto_invokes_retry(self):
        region, chaos, inner = self._quarantined_region()
        svc = region.service
        try:
            # trip the breaker with failing reads, then heal the store and
            # drive probe traffic: the open->closed edge must re-post the
            # quarantined pages with NO manual retry_quarantined call.
            for p in range(8, 12):
                with pytest.raises(IOError):
                    region.read(p * PAGE, PAGE)
            assert next(iter_breakers(region.store)).state == "open"
            chaos.revive()
            time.sleep(0.25)
            for p in range(8, 12):
                region.read(p * PAGE, PAGE)
            deadline = time.time() + 3
            while time.time() < deadline:
                s = svc.stats
                if s.quarantined_pages == 0 and s.quarantine_retries > 0:
                    break
                time.sleep(0.02)
            s = svc.stats
            assert s.quarantine_retries == 4
            assert s.quarantined_pages == 0
            svc.flush_region(region)
            chk = np.empty(PAGE, np.uint8)
            inner.read_into(0, chk)
            assert (chk == 42).all()
        finally:
            uunmap(region)

    def test_retry_skips_pinned_and_clean_pages(self):
        region, chaos, _ = self._quarantined_region()
        svc = region.service
        try:
            chaos.revive()
            time.sleep(0.25)                      # breaker reset window
            lease = svc.lease_page(region, 0)     # pins quarantined page 0
            try:
                n = svc.retry_quarantined(region)
                assert n == 3                     # pinned page skipped
            finally:
                lease.release()
            deadline = time.time() + 3
            while time.time() < deadline and svc.stats.quarantined_pages > 1:
                time.sleep(0.02)
            assert svc.stats.quarantined_pages == 1
            assert svc.retry_quarantined(region) == 1
            deadline = time.time() + 3
            while time.time() < deadline and svc.stats.quarantined_pages:
                time.sleep(0.02)
        finally:
            uunmap(region)


# ------------------------------------------------- bounded close (§17.7)


class TestBoundedClose:
    def test_close_mid_stall_returns_and_warns(self):
        """service.close() during an in-flight fill stalled inside the
        store must return within the join deadline, warn loudly, and name
        the leaked thread — not hang until the store call finishes."""
        chaos = ChaosStore(_mem(32 * PAGE), seed=1,
                           latency_spike_rate=1.0, latency_spike_s=3.0)
        cfg = UMapConfig(page_size=PAGE, buffer_size=8 * PAGE)
        region = umap(chaos, config=cfg)
        svc = region.service
        svc.request_fills(region, [0, 1])
        time.sleep(0.1)                           # filler now inside sleep
        t0 = time.monotonic()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            svc.close(join_timeout_s=0.3)
        dt = time.monotonic() - t0
        assert dt < 2.0, f"close took {dt:.1f}s — unbounded join"
        assert svc.leaked_threads, "leaked filler not recorded"
        assert any("umap-filler" in name for name in svc.leaked_threads)
        msgs = [str(w.message) for w in caught]
        assert any("leak" in m or "thread" in m for m in msgs), msgs

    def test_clean_close_leaks_nothing(self):
        region = umap(_mem(32 * PAGE), config=UMapConfig(
            page_size=PAGE, buffer_size=8 * PAGE))
        svc = region.service
        region.read(0, PAGE)
        uunmap(region)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            svc.close(join_timeout_s=5.0)
        assert svc.leaked_threads == []
        assert not caught

