"""Property-based tests (hypothesis) for the paging core's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    ClockPolicy,
    FifoPolicy,
    HostArrayStore,
    LruPolicy,
    SlidingWindowPolicy,
    UMapConfig,
    umap,
    uunmap,
)

REGION_BYTES = 64 * 512  # 64 pages of 512B


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["read", "write", "prefetch", "flush"]),
        st.integers(min_value=0, max_value=REGION_BYTES - 1),
        st.integers(min_value=1, max_value=2048),
    ),
    min_size=1,
    max_size=60,
)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy, slots=st.integers(min_value=2, max_value=16),
       policy=st.sampled_from(["fifo", "lru", "clock"]))
def test_region_matches_numpy_oracle(ops, slots, policy):
    """Any op sequence + final flush must equal a plain numpy mirror."""
    base = (np.arange(REGION_BYTES) % 255).astype(np.uint8)
    store = HostArrayStore(base.copy())
    mirror = base.copy()
    cfg = UMapConfig(page_size=512, buffer_size=slots * 512,
                     num_fillers=3, num_evictors=2, eviction_policy=policy)
    r = umap(store, config=cfg)
    try:
        for kind, off, n in ops:
            n = min(n, REGION_BYTES - off)
            if kind == "read":
                got = r.read(off, n)
                assert np.array_equal(got, mirror[off : off + n])
            elif kind == "write":
                val = np.full(n, (off + n) % 256, np.uint8)
                r.write(off, val)
                mirror[off : off + n] = val
            elif kind == "prefetch":
                r.prefetch(off, n)
            elif kind == "flush":
                r.flush()
        r.flush()
        final = np.empty(REGION_BYTES, np.uint8)
        store.read_into(0, final)
        assert np.array_equal(final, mirror)
        # buffer invariants
        assert r.service.buffer.used_slots <= slots
        assert 0 <= r.service.table.dirty_count <= slots
    finally:
        uunmap(r)


@settings(max_examples=50, deadline=None)
@given(
    installs=st.lists(st.integers(min_value=0, max_value=30), min_size=1,
                      max_size=30, unique=True),
    touches=st.lists(st.integers(min_value=0, max_value=30), max_size=30),
)
def test_eviction_policies_basic_laws(installs, touches):
    """Victims must be resident; LRU must not pick the most recent touch."""
    for cls in (FifoPolicy, LruPolicy, ClockPolicy, SlidingWindowPolicy):
        pol = cls()
        resident = set()
        for p in installs:
            pol.on_install((0, p))
            resident.add((0, p))
        for p in touches:
            pol.on_touch((0, p))
        victims = pol.pick_victims(3, lambda k: k in resident)
        assert len(victims) == min(3, len(resident))
        assert all(v in resident for v in victims)
        assert len(set(victims)) == len(victims)
        # removal really removes
        for v in victims:
            pol.on_remove(v)
            resident.discard(v)
        again = pol.pick_victims(len(resident) + 3, lambda k: k in resident)
        assert set(again) == resident


def test_lru_order_is_least_recent_first():
    pol = LruPolicy()
    for p in range(5):
        pol.on_install((0, p))
    pol.on_touch((0, 0))      # 0 becomes most recent
    victims = pol.pick_victims(4, lambda k: True)
    assert victims == [(0, 1), (0, 2), (0, 3), (0, 4)]


def test_fifo_ignores_touches():
    pol = FifoPolicy()
    for p in range(4):
        pol.on_install((0, p))
    pol.on_touch((0, 0))
    assert pol.pick_victims(1, lambda k: True) == [(0, 0)]


def test_swa_evicts_lowest_pages_first():
    pol = SlidingWindowPolicy()
    for p in (9, 2, 7, 4):
        pol.on_install((0, p))
    assert pol.pick_victims(2, lambda k: True) == [(0, 2), (0, 4)]


def test_clock_second_chance():
    pol = ClockPolicy()
    for p in range(3):
        pol.on_install((0, p))
    # first sweep clears ref bits, so with all bits set the policy still
    # returns a victim (two-sweep behavior)
    v = pol.pick_victims(1, lambda k: True)
    assert len(v) == 1
