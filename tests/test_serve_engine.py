"""Fault-injected serving harness: multi-tenant engine under churn.

Every test drives the REAL engine (smoke-config model, jitted decode) through
injected faults — pool exhaustion mid-decode, deadline storms, fair-share
watermark crossings, prefix divergence — and asserts the two properties the
serving layer must never lose (DESIGN.md §16):

  * nothing is lost: every submitted request retires exactly once (finished
    or expired), pages drain back to the free list;
  * determinism: greedy decode through eviction/requeue/COW produces the
    same bytes a sequential single-tenant reference produces.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.models as M
from repro.configs.registry import get_smoke_config
from repro.core import HostArrayStore, TieredStore, UMapConfig, umap, uunmap
from repro.serve.engine import EngineConfig, Request, ServeEngine, Tenant
from repro.telemetry import TelemetryRegistry


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params


def reference_generate(cfg, params, prompt, max_new_tokens):
    """Sequential single-request greedy decode (contiguous cache)."""
    toks = list(prompt)
    cache = M.init_cache(cfg, 1, 96)
    batch = {"tokens": jnp.asarray([toks[:-1]], jnp.int32)}
    _, cache = M.prefill(cfg, params, batch, cache)
    out = []
    cur = len(toks) - 1
    for _ in range(max_new_tokens):
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.asarray([toks[-1]], jnp.int32),
            jnp.asarray([cur], jnp.int32))
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        toks.append(nxt)
        cur += 1
    return out


def assert_none_lost(eng, submitted):
    """Every submitted request retired exactly once; pool fully drained
    (scratch + registered prefixes are the only pages left)."""
    assert not eng.waiting and not eng.active
    assert len(eng.finished) == len(submitted)
    assert {r.rid for r in eng.finished} == {r.rid for r in submitted}
    prefix_pages = sum(len(e.pages) for e in eng._prefixes.values())
    assert eng.allocator.used_pages == 1 + prefix_pages


# ------------------------------------------------- live-mutation regression


def test_adjacent_lanes_boundary_under_exhaustion(model):
    """Regression for the `live.remove(rid)` while iterating bug: two
    adjacent lanes cross a page boundary in the same step with the pool
    exhausted.  The pre-fix loop skipped the lane after the evicted one, so
    its boundary page was silently never allocated and its generation
    diverged after the eventual requeue.  Post-fix: every request still
    finishes with byte-identical output and correct page accounting."""
    cfg, params = model
    ps = 4
    # per request: ceil((4+1)/4)+1 = 3 pages; scratch + 2*3 = 7 fills the
    # pool exactly, so the first same-step double boundary crossing faults
    ecfg = EngineConfig(max_batch=2, page_size=ps, num_pages=7,
                        max_pages_per_seq=8, prefill_bucket=8,
                        prefix_sharing=False)
    eng = ServeEngine(cfg, params, ecfg)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=10) for i in range(2)]
    for r in reqs:
        eng.submit(r)

    # drive by hand and check the allocation invariant after every step:
    # every live lane's next write position is backed by an allocated page
    # (the bug left the skipped lane's table one page short)
    for _ in range(200):
        if not eng.waiting and not eng.active:
            break
        eng.step()
        for rid in eng.active:
            pos = eng.seq_len[rid]
            assert len(eng.allocator.pages_of(rid)) > pos // ps, \
                f"lane of rid {rid} missed its boundary page allocation"
    assert_none_lost(eng, reqs)
    assert eng.stats["evictions"] >= 1, "scenario must actually exhaust"
    for r in reqs:
        assert r.generated == reference_generate(cfg, params, r.prompt, 10), \
            f"rid {r.rid} diverged after eviction/requeue"


# ------------------------------------------------------------ deadline storm


def test_deadline_storm_requeue_churn(model):
    """Every request under an impossible-deadline storm finishes or is
    requeued — none lost — and restarts are bounded by max_restarts."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8,
                        max_restarts=3, slo_admission=False)
    eng = ServeEngine(cfg, params, ecfg)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(8):
        p = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
        # half the storm can never meet its deadline (already expired)
        dl = -1.0 if i % 2 else None
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=4, deadline_s=dl))
        eng.submit(reqs[-1])
    eng.run_until_drained(max_steps=500)
    assert_none_lost(eng, reqs)
    assert eng.stats["requeues"] >= 1
    for r in reqs:
        assert r.restarts <= ecfg.max_restarts
        if r.deadline_s is None:
            assert not r.expired and r.done
        else:
            # impossible deadline: bounded restarts, then expired (not lost)
            assert r.expired and r.restarts == ecfg.max_restarts
            assert r.slo_miss
    assert eng.stats["expired"] == sum(1 for r in reqs if r.expired)


# ------------------------------------------------- watermark gate hysteresis


def test_global_watermark_hysteresis(model):
    """Admission pauses at high water and stays paused until occupancy
    drops below LOW water — crossing back above low alone must not flap."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=20,
                        max_pages_per_seq=8, admit_high_water=0.5,
                        admit_low_water=0.25)
    eng = ServeEngine(cfg, params, ecfg)
    a = eng.allocator
    a.alloc(99, 9)                       # occupancy 10/20 = 0.5 >= high
    assert not eng._watermark_gate()
    assert eng.stats["admission_pauses"] == 1
    a.free_prefix(99, 4)                 # 6/20 = 0.3: above low, stays paused
    assert not eng._watermark_gate()
    a.free_prefix(99, 2)                 # 4/20 = 0.2 < low: resumes
    assert eng._watermark_gate()
    assert eng.stats["admission_pauses"] == 1, "resume must not re-count"


def test_tenant_fair_share_gate_hysteresis(model):
    """Per-tenant gate: a tenant crossing HIGH water of its fair share is
    paused (counted per-tenant) without pausing the other tenant, and
    resumes only below LOW water of its share."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=17,
                        max_pages_per_seq=8, admit_high_water=0.85,
                        admit_low_water=0.60)
    eng = ServeEngine(cfg, params, ecfg)
    eng.add_tenant(Tenant("a", weight=1.0))
    eng.add_tenant(Tenant("b", weight=1.0))
    # 16 shareable pages, equal weights (default tenant included): a's fair
    # share comes from fair_shares; consume pages as a's live sequence
    share = eng._fair_share_pages()["a"]
    rid = 1
    eng.active[rid] = Request(rid=rid, prompt=np.arange(2, dtype=np.int32),
                              tenant="a")
    high = int(np.ceil(ecfg.admit_high_water * share))
    eng.allocator.alloc(rid, high)
    assert not eng._tenant_gate("a"), "tenant a must pause at high water"
    assert eng._tenant_gate("b"), "tenant b unaffected by a's pressure"
    assert eng.stats["per_tenant"]["a"]["admission_pauses"] == 1
    assert eng.stats["per_tenant"]["b"]["admission_pauses"] == 0
    # drop between low and high: hysteresis holds the pause
    between = int(np.ceil(ecfg.admit_low_water * share))
    eng.allocator.free_prefix(rid, high - between)
    assert not eng._tenant_gate("a")
    # below low water: resumes, counter unchanged
    eng.allocator.free_prefix(rid, 1)
    assert eng._tenant_gate("a")
    assert eng.stats["per_tenant"]["a"]["admission_pauses"] == 1


# ------------------------------------------------------ multi-tenant storm


def test_multi_tenant_storm_byte_identical(model):
    """Seeded 3-tenant storm under pool pressure: generations are
    byte-identical to a sequential single-tenant reference run, across
    admission reordering, victim eviction, requeues, and COW sharing."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=48,
                        max_pages_per_seq=16, prefill_bucket=8)
    eng = ServeEngine(cfg, params, ecfg)
    eng.add_tenant(Tenant("gold", weight=4.0, priority=2))
    eng.add_tenant(Tenant("silver", weight=2.0, priority=1))
    eng.add_tenant(Tenant("bronze", weight=1.0, priority=0))
    rng = np.random.default_rng(11)
    prefix = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    eng.register_prefix(prefix, tenant="gold")
    reqs = []
    for i in range(12):
        tenant = ("gold", "silver", "bronze")[i % 3]
        if i % 2:
            p = np.concatenate(
                [prefix, rng.integers(1, cfg.vocab_size, 3).astype(np.int32)])
        else:
            p = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=5, tenant=tenant))
        eng.submit(reqs[-1])
    eng.run_until_drained(max_steps=1000)
    assert_none_lost(eng, reqs)
    assert eng.stats["prefix_hits"] >= 1
    for r in reqs:
        ref = reference_generate(cfg, params, r.prompt, 5)
        assert r.generated == ref, f"rid {r.rid} ({r.tenant}) diverged"
    # per-tenant accounting closes against the aggregate
    per = eng.stats["per_tenant"]
    assert sum(t["finished"] for t in per.values()) == len(reqs)
    assert sum(t["tokens_generated"] for t in per.values()) == 5 * len(reqs)


# ------------------------------------------------------- prefix COW sharing


def test_prefix_sharing_saves_pages_and_matches_no_sharing(model):
    """COW prefix sharing reduces peak pool pages while generating the
    exact bytes a no-sharing engine generates."""
    cfg, params = model

    def run(sharing):
        ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=96,
                            max_pages_per_seq=16, prefill_bucket=8,
                            prefix_sharing=sharing)
        eng = ServeEngine(cfg, params, ecfg)
        rng = np.random.default_rng(5)
        # deliberately NOT page-aligned (10 % 4 != 0) so the prefill tail
        # rewrites the boundary page (alloc-side COW); prompts equal to the
        # prefix make the first decode write land in a shared page
        # (device-copy COW)
        prefix = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
        if sharing:
            eng.register_prefix(prefix)
        reqs = []
        for i in range(8):
            p = prefix if i % 2 else np.concatenate(
                [prefix, rng.integers(1, cfg.vocab_size, 3).astype(np.int32)])
            reqs.append(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
            eng.submit(reqs[-1])
        eng.run_until_drained(max_steps=500)
        assert_none_lost(eng, reqs)
        return eng, [r.generated for r in sorted(reqs, key=lambda r: r.rid)]

    shared_eng, shared_gen = run(True)
    plain_eng, plain_gen = run(False)
    assert shared_gen == plain_gen, "sharing changed generated bytes"
    assert shared_eng.stats["prefix_hits"] == 8
    assert shared_eng.stats["shared_pages_mapped"] > 0
    assert shared_eng.stats["cow_copies"] > 0, "divergent writes must COW"
    assert (shared_eng.stats["peak_pages_used"]
            < plain_eng.stats["peak_pages_used"]), \
        "sharing must reduce peak pool consumption"


def test_drop_prefix_refcounts_survive_live_sharers(model):
    """Dropping a prefix while sequences still share its pages must not
    free them out from under the sharers (refcount keeps them live)."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8)
    eng = ServeEngine(cfg, params, ecfg)
    rng = np.random.default_rng(9)
    prefix = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    key = eng.register_prefix(prefix)
    p = np.concatenate([prefix,
                        rng.integers(1, cfg.vocab_size, 2).astype(np.int32)])
    req = Request(rid=0, prompt=p, max_new_tokens=6)
    eng.submit(req)
    eng.step()                                   # admit + first decode
    assert eng.stats["prefix_hits"] == 1
    eng.drop_prefix(key)                         # prefix gone, sharer lives
    eng.run_until_drained(max_steps=200)
    assert req.generated == reference_generate(cfg, params, p, 6)
    assert eng.allocator.used_pages == 1         # everything drained


# ------------------------------------------------------------- tier pinning


def test_priority_tenant_prefix_pinned_fast_tier(model):
    """A pin_fast tenant's registered prefix is persisted into the prefix
    region and pinned into the fast tier via the §14.3 tier-hint path."""
    cfg, params = model
    PS = 4096
    slow = HostArrayStore(np.zeros(16 * PS, np.uint8))
    fast = HostArrayStore(np.zeros(4 * PS, np.uint8))
    store = TieredStore(fast=fast, slow=slow, extent_size=PS)
    region = umap(store, config=UMapConfig(page_size=PS,
                                           buffer_size=4 * PS,
                                           num_fillers=1, num_evictors=1))
    try:
        ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=64,
                            max_pages_per_seq=16, prefill_bucket=8)
        eng = ServeEngine(cfg, params, ecfg, prefix_region=region)
        eng.add_tenant(Tenant("gold", weight=2.0, priority=1, pin_fast=True))
        rng = np.random.default_rng(13)
        prefix = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
        key = eng.register_prefix(prefix, tenant="gold")
        assert eng._prefixes[key].pinned
        region.flush()
        st = store.tier_stats()
        assert st["pinned_fast"] > 0, "pin_fast hint did not reach the tier"
        # the persisted bytes round-trip through the region
        got = np.frombuffer(region.read(0, prefix.nbytes), np.int32)
        np.testing.assert_array_equal(got, prefix)
    finally:
        uunmap(region)


# ------------------------------------------------------------ SLO admission


def test_slo_admission_orders_by_headroom(model):
    """With one free lane, the tight-but-feasible deadline is admitted ahead
    of earlier-submitted laxer requests; infeasible deadlines are deferred
    (counted) but still finish — nothing starves."""
    cfg, params = model
    # seed estimates of 1 s/step make a 2 s deadline infeasible for a
    # 2-token request (est = 1 + 2*1 = 3 s) while 30 s / 120 s are feasible
    ecfg = EngineConfig(max_batch=1, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8,
                        est_step_s=1.0, est_prefill_s=1.0, slo_safety=1.0)
    eng = ServeEngine(cfg, params, ecfg)
    rng = np.random.default_rng(17)
    mk = lambda rid, dl: Request(
        rid=rid, prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
        max_new_tokens=2, deadline_s=dl)
    lax = mk(0, 120.0)
    tight = mk(1, 30.0)
    infeasible = mk(2, 2.0)      # headroom > 0 but < estimated service time
    for r in (lax, tight, infeasible):
        eng.submit(r)
    eng.step()
    assert tight.rid in eng.lane_of or tight.done, \
        "tightest feasible deadline must win the single lane"
    assert eng.stats["slo_deferrals"] >= 1, \
        "infeasible headroom must be deferred in the strict pass"
    eng.run_until_drained(max_steps=500)
    assert_none_lost(eng, [lax, tight, infeasible])
    assert all(r.done or r.expired for r in (lax, tight, infeasible))


# ----------------------------------------------------- telemetry end-to-end


def test_serve_collector_parity_after_drained_run(model):
    """After a drained multi-tenant run, the scraped exposition's counter
    families equal the engine's stats dict — per-tenant labels included
    (the aggregate == sum(per_shard) parity pattern applied to serving)."""
    from test_telemetry import parse_exposition

    cfg, params = model
    ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8)
    eng = ServeEngine(cfg, params, ecfg)
    eng.add_tenant(Tenant("gold", weight=2.0, priority=1))
    eng.add_tenant(Tenant("bronze", weight=1.0))
    rng = np.random.default_rng(19)
    prefix = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    eng.register_prefix(prefix, tenant="gold")
    reqs = []
    for i in range(6):
        p = np.concatenate(
            [prefix, rng.integers(1, cfg.vocab_size, 2).astype(np.int32)]) \
            if i % 2 else rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=3,
                            tenant="gold" if i % 2 else "bronze"))
        eng.submit(reqs[-1])
    reg = TelemetryRegistry()
    eng.register_telemetry(registry=reg, label="t")
    eng.run_until_drained(max_steps=500)
    assert_none_lost(eng, reqs)

    fams = parse_exposition(reg.render())
    flat = {k: v for k, v in eng.stats.items() if k != "per_tenant"}
    scraped_aggregate = {
        "steps": "umap_serve_steps_total",
        "prefills": "umap_serve_prefills_total",
        "evictions": "umap_serve_evictions_total",
        "requeues": "umap_serve_requeues_total",
        "admission_pauses": "umap_serve_admission_pauses_total",
        "slo_deferrals": "umap_serve_slo_deferrals_total",
        "slo_misses": "umap_serve_slo_misses_total",
        "expired": "umap_serve_expired_total",
        "victim_evictions": "umap_serve_victim_evictions_total",
        "cow_copies": "umap_serve_cow_copies_total",
        "shared_pages_mapped": "umap_serve_shared_pages_mapped_total",
        "prefix_hits": "umap_serve_prefix_hits_total",
        "prefix_drops": "umap_serve_prefix_drops_total",
        "peak_pages_used": "umap_serve_peak_pages_used",
    }
    for key, fam in scraped_aggregate.items():
        assert fams[fam]["samples"][0][2] == float(flat[key]), (key, fam)
    assert fams["umap_serve_finished_requests_total"]["samples"][0][2] \
        == len(eng.finished)
    # per-tenant labels: every tenant appears, values equal the stats dict
    for key, fam in (("prefills", "umap_serve_tenant_prefills_total"),
                     ("finished", "umap_serve_tenant_finished_total"),
                     ("tokens_generated",
                      "umap_serve_tenant_tokens_generated_total")):
        got = {lab["tenant"]: v for _, lab, v in fams[fam]["samples"]}
        want = {t: float(st[key])
                for t, st in eng.stats["per_tenant"].items()}
        assert got == want, (key, fam)


# ------------------------------------------ degraded-mode admission (§17.9)


class _DegradedPagingSvc:
    """Duck-typed paging service: only what paging_degraded() probes."""

    def __init__(self):
        self.open = 0

    def open_breakers(self):
        return self.open


def _mk_deadline_req(cfg, rng, rid, deadline_s):
    return Request(rid=rid,
                   prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                   max_new_tokens=2, deadline_s=deadline_s)


def test_degraded_paging_sheds_infeasible_deadlines(model):
    """While the paging service reports an open breaker, service-time
    estimates carry degrade_multiplier: a deadline that is feasible when
    healthy (est 3 s < 10 s) becomes infeasible degraded (est 30 s) and is
    shed at admission — retired terminally via shed_requests, never
    counted as restart exhaustion."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8,
                        est_step_s=1.0, est_prefill_s=1.0, slo_safety=1.0,
                        degrade_multiplier=10.0)
    svc = _DegradedPagingSvc()
    eng = ServeEngine(cfg, params, ecfg, paging_service=svc)
    rng = np.random.default_rng(23)
    req = _mk_deadline_req(cfg, rng, 0, deadline_s=10.0)
    svc.open = 1
    assert eng.paging_degraded() is True
    eng.submit(req)
    eng.step()
    assert eng.stats["shed_requests"] == 1
    assert eng.stats["per_tenant"]["default"]["shed_requests"] == 1
    assert req.expired and req.slo_miss and req in eng.finished
    assert eng.stats["expired"] == 0, "shed is not restart exhaustion"
    assert_none_lost(eng, [req])


def test_healthy_paging_admits_same_deadline(model):
    """The identical request sails through admission when no breaker is
    open — the degraded multiplier must not leak into the healthy path."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8,
                        est_step_s=1.0, est_prefill_s=1.0, slo_safety=1.0,
                        degrade_multiplier=10.0)
    svc = _DegradedPagingSvc()
    eng = ServeEngine(cfg, params, ecfg, paging_service=svc)
    rng = np.random.default_rng(23)
    req = _mk_deadline_req(cfg, rng, 0, deadline_s=10.0)
    eng.submit(req)
    eng.run_until_drained(max_steps=200)
    assert eng.stats["shed_requests"] == 0
    assert req.done and not req.expired
    assert_none_lost(eng, [req])


def test_degrade_shed_opt_out_keeps_request(model):
    """degrade_shed=False: the degraded estimate may defer the request but
    never sheds it — it still retires through the normal lifecycle."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=2, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8,
                        est_step_s=1.0, est_prefill_s=1.0, slo_safety=1.0,
                        degrade_multiplier=10.0, degrade_shed=False)
    svc = _DegradedPagingSvc()
    eng = ServeEngine(cfg, params, ecfg, paging_service=svc)
    svc.open = 1
    rng = np.random.default_rng(23)
    req = _mk_deadline_req(cfg, rng, 0, deadline_s=10.0)
    eng.submit(req)
    eng.run_until_drained(max_steps=200)
    assert eng.stats["shed_requests"] == 0
    assert req.done or req.expired       # retired, never silently dropped
    assert_none_lost(eng, [req])


def test_paging_degraded_probe_is_defensive(model):
    """A paging service whose health probe raises must read as healthy —
    the degradation probe can never take the engine down."""
    cfg, params = model
    ecfg = EngineConfig(max_batch=1, page_size=4, num_pages=64,
                        max_pages_per_seq=16, prefill_bucket=8)

    class _Broken:
        def open_breakers(self):
            raise RuntimeError("probe exploded")

    eng = ServeEngine(cfg, params, ecfg, paging_service=_Broken())
    assert eng.paging_degraded() is False
    eng2 = ServeEngine(cfg, params, ecfg)          # no service wired at all
    assert eng2.paging_degraded() is False
