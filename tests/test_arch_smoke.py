"""Per-architecture smoke tests: reduced same-family config, one forward /
train-gradient step on CPU, asserting output shapes + finiteness (assignment
requirement f).  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs.base import SHAPES
from repro.configs.registry import (
    ARCH_IDS,
    get_config,
    get_smoke_config,
    runnable_cells,
    skipped_cells,
)

B, S = 2, 16


def make_batch(cfg, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        if cfg.mrope_sections is not None:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, 3, S)).copy()
            batch["positions"] = jnp.asarray(pos)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, 12, cfg.d_model)), jnp.float32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    hid, aux = M.forward_train(cfg, params, batch)
    assert hid.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hid)).all(), f"{arch}: non-finite hidden"
    logits = M.lm_logits(cfg, params, hid)
    assert logits.shape == (B, S, cfg.padded_vocab)
    # padded vocab region masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size :].max()) < -1e8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_gradient(arch):
    """One loss+grad step: finite loss, finite grads, params update."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)

    def loss_fn(p):
        hid, aux = M.forward_train(cfg, p, batch)
        logits = M.lm_logits(cfg, p, hid).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(lp, batch["labels"][..., None], axis=-1)
        loss = -ll.mean()
        if aux:
            loss = loss + 0.01 * aux["moe_lb_loss"] + 0.001 * aux["moe_z_loss"]
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    """prefill(s-1) + decode(1) must equal the full forward's last logits."""
    cfg = get_smoke_config(arch)
    if cfg.input_mode == "embeds":
        pytest.skip("stub-frontend archs decode from token embeds; covered "
                    "by test_models_decode paths")
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    hid, _ = M.forward_train(cfg, params, batch)
    ref = M.lm_logits(cfg, params, hid)[:, -1]

    cache = M.init_cache(cfg, B, S + 4, memory_len=12 if cfg.is_encdec else None)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    _, cache = M.prefill(cfg, params, pre, cache)
    cur = jnp.full((B,), S - 1 + cfg.num_meta_tokens, jnp.int32)
    logits, _ = M.decode_step(cfg, params, cache, batch["tokens"][:, -1], cur)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref), rtol=2e-3, atol=2e-4)


def test_full_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    expect = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("phi3.5-moe-42b-a6.6b").num_experts == 16
    assert get_config("seamless-m4t-medium").enc_layers == 12


def test_cell_matrix_accounting():
    """40 assigned cells = 33 runnable + 7 documented long_500k skips."""
    cells = runnable_cells()
    skips = skipped_cells()
    assert len(cells) + len(skips) == len(ARCH_IDS) * len(SHAPES) == 40
    assert len(cells) == 33
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"hymba-1.5b", "mixtral-8x7b", "xlstm-1.3b"}


def test_padding_is_function_preserving():
    """Padded Q heads (zero o_proj rows) leave the function unchanged."""
    cfg_pad = get_smoke_config("smollm-135m")          # 3 heads -> pad to 4
    cfg_nopad = cfg_pad.replace(head_pad_multiple=1)   # no padding
    assert cfg_pad.padded_heads == 4 and cfg_nopad.padded_heads == 3
    p_nopad = M.init_params(cfg_nopad, jax.random.key(0))
    p_pad = jax.tree.map(lambda x: x, p_nopad)  # copy

    # embed padded params from unpadded ones: wq columns 0-pad, wo rows 0-pad
    def pad_attn(attn):
        out = dict(attn)
        H, D, E = 4, cfg_pad.head_dim, cfg_pad.d_model

        def pad_one(wq, wo):
            wq = wq.reshape(E, 3, D)
            wq = jnp.concatenate([wq, jnp.zeros((E, 1, D), wq.dtype)],
                                 axis=1).reshape(E, H * D)
            wo = wo.reshape(3, D, E)
            wo = jnp.concatenate([wo, jnp.zeros((1, D, E), wo.dtype)],
                                 axis=0).reshape(H * D, E)
            return wq, wo

        out["wq"], out["wo"] = jax.vmap(pad_one)(attn["wq"], attn["wo"])
        return out

    segs = []
    for seg_p in p_nopad["segments"]:
        sp = dict(seg_p)
        sp["attn"] = pad_attn(seg_p["attn"])
        segs.append(sp)
    p_pad = dict(p_nopad)
    p_pad["segments"] = segs

    batch = make_batch(cfg_nopad)
    h0, _ = M.forward_train(cfg_nopad, p_nopad, batch)
    h1, _ = M.forward_train(cfg_pad, p_pad, batch)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-5, atol=1e-5)
