"""Telemetry substrate (DESIGN.md §15): one test class per collector
(omnistat-style per-collector harness), plus the metric primitives, the
registry, the HTTP exporter end-to-end, the scrape-path lock rules
(a scrape completes while every shard lock is held by someone else), a
fault-storm-while-scraping run asserting scrapes neither block fills nor
perturb snapshot parity, and the ``UMAP_TELEMETRY_PORT`` autostart.
"""

import re
import socket
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import telemetry
from repro.core import HostArrayStore, UMapConfig, umap, uunmap
from repro.core.store import TieredStore
from repro.telemetry import (
    CONTENT_TYPE,
    TelemetryExporter,
    TelemetryRegistry,
)
from repro.telemetry.collectors import (
    LeaseCollector,
    PagerCollector,
    ProcessCollector,
    ResilienceCollector,
    ServeCollector,
    TieringCollector,
)
from repro.telemetry.metrics import (
    DEFAULT_BUCKETS,
    HistogramState,
    MetricFamily,
    escape_label_value,
    format_value,
    validate_label_name,
    validate_metric_name,
)

PS = 4096

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})? (?P<value>\S+)$")


def parse_exposition(text: str):
    """Prometheus text -> {family: {"type": ..., "samples":
    [(series_name, {label: value}, float)]}}; also validates that every
    sample line is preceded by its family's HELP/TYPE header."""
    families, current = {}, None
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            current = line.split(" ", 3)[2]
            families.setdefault(current, {"type": None, "samples": []})
        elif line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            assert name == current, f"TYPE {name} without its HELP"
            families[name]["type"] = kind
        else:
            m = _SAMPLE_RE.match(line)
            assert m, f"unparseable sample line: {line!r}"
            sname = m.group("name")
            assert current and sname.startswith(current), \
                f"sample {sname} outside its family block ({current})"
            labels = {}
            if m.group("labels"):
                for part in re.findall(r'(\w+)="((?:[^"\\]|\\.)*)"',
                                       m.group("labels")):
                    labels[part[0]] = part[1]
            families[current]["samples"].append(
                (sname, labels, float(m.group("value"))))
    return families


def families_of(collector):
    out = {}
    for fam in collector.collect():
        assert fam.name not in out, f"duplicate family {fam.name}"
        out[fam.name] = fam
    return out


def make_region(npages=64, shards=4, tiered=False, **cfg_kw):
    data = (np.arange(npages * PS) % 251).astype(np.uint8)
    store = HostArrayStore(data)
    if tiered:
        fast = HostArrayStore(np.zeros(npages * PS // 4, np.uint8))
        store = TieredStore(fast=fast, slow=store, extent_size=4 * PS)
    cfg = UMapConfig(page_size=PS, buffer_size=npages * PS, num_fillers=2,
                     num_evictors=1, shards=shards, **cfg_kw)
    return umap(store, config=cfg)


# --------------------------------------------------------------- primitives


class TestMetricPrimitives:
    def test_metric_and_label_name_validation(self):
        validate_metric_name("umap_pager_demand_faults_total")
        for bad in ("0abc", "has space", "dash-ed", ""):
            with pytest.raises(ValueError):
                validate_metric_name(bad)
        validate_label_name("shard")
        for bad in ("__reserved", "0x", "a-b"):
            with pytest.raises(ValueError):
                validate_label_name(bad)

    def test_label_value_escaping(self):
        assert escape_label_value('a"b\\c\nd') == 'a\\"b\\\\c\\nd'

    def test_format_value(self):
        assert format_value(3) == "3"
        assert format_value(2.0) == "2"
        assert format_value(0.25) == "0.25"
        assert format_value(float("inf")) == "+Inf"
        assert format_value(float("-inf")) == "-Inf"
        assert format_value(float("nan")) == "NaN"

    def test_family_render_golden(self):
        fam = MetricFamily("umap_x_total", "counter", "Help text",
                           {"source": "s0"})
        fam.add(7, shard=3)
        assert fam.render() == (
            "# HELP umap_x_total Help text\n"
            "# TYPE umap_x_total counter\n"
            'umap_x_total{shard="3",source="s0"} 7\n')

    def test_family_rejects_bad_kind(self):
        with pytest.raises(ValueError):
            MetricFamily("umap_x", "summary", "h")

    def test_histogram_buckets_are_cumulative(self):
        h = HistogramState(buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        fam = h.to_family("umap_d_seconds", "h")
        by_le = {lab["le"]: val for sfx, lab, val in
                 ((s, la, v) for s, la, v in fam.samples) if sfx == "_bucket"}
        assert by_le == {"0.01": 1, "0.1": 2, "1": 3, "+Inf": 4}
        sums = {sfx: v for sfx, _, v in fam.samples if sfx in ("_sum", "_count")}
        assert sums["_count"] == 4 and sums["_sum"] == pytest.approx(5.555)

    def test_default_buckets_sorted(self):
        assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)


# ----------------------------------------------------------------- registry


class TestRegistry:
    def test_register_dedupes_names_and_unregisters(self):
        reg = TelemetryRegistry()
        a = reg.register(ProcessCollector(label="x"))
        b = reg.register(ProcessCollector(label="x"))
        assert a == "process:x" and b == "process:x#2"
        assert set(reg.collector_names()) == {a, b}
        assert reg.unregister(b) and not reg.unregister(b)
        assert reg.collector_names() == [a]

    def test_merges_same_family_from_two_collectors(self):
        class One(ProcessCollector):
            kind = "one"

            def collect(self):
                return [self.c1("umap_thing_total", "h", 1)]

        reg = TelemetryRegistry()
        reg.register(One(label="a"))
        reg.register(One(label="b"))
        fams = parse_exposition(reg.render())
        srcs = {lab["source"] for _, lab, _ in
                fams["umap_thing_total"]["samples"]}
        assert srcs == {"a", "b"}
        # merged into ONE family block: exactly one TYPE line
        assert reg.render().count("# TYPE umap_thing_total counter") == 1

    def test_collector_failure_is_counted_not_fatal(self):
        class Broken:
            name = "broken:x"

            def collect(self):
                raise RuntimeError("boom")

        reg = TelemetryRegistry()
        reg.register(Broken())
        reg.register(ProcessCollector(label="ok"))
        fams = parse_exposition(reg.render())
        assert "umap_process_threads" in fams          # scrape survived
        errs = {lab["collector"]: v for _, lab, v in
                fams["umap_telemetry_collect_errors_total"]["samples"]}
        assert errs["broken:x"] == 1

    def test_self_telemetry_scrapes_and_duration(self):
        reg = TelemetryRegistry()
        first = parse_exposition(reg.render())
        second = parse_exposition(reg.render())
        n1 = first["umap_telemetry_scrapes_total"]["samples"][0][2]
        n2 = second["umap_telemetry_scrapes_total"]["samples"][0][2]
        assert (n1, n2) == (1, 2)
        hist = second["umap_telemetry_scrape_duration_seconds"]
        assert hist["type"] == "histogram"
        inf = [v for s, lab, v in hist["samples"]
               if lab.get("le") == "+Inf"]
        assert inf == [1]                              # first render observed

    def test_type_conflict_keeps_first_and_counts(self):
        class C1(ProcessCollector):
            def collect(self):
                return [self.c1("umap_conflict", "h", 1)]

        class C2(ProcessCollector):
            def collect(self):
                return [self.g1("umap_conflict", "h", 2)]

        reg = TelemetryRegistry()
        reg.register(C1(label="a"), name="a")
        reg.register(C2(label="b"), name="b")
        fams = parse_exposition(reg.render())
        assert fams["umap_conflict"]["type"] == "counter"
        errs = {lab["collector"] for _, lab, _ in
                fams["umap_telemetry_collect_errors_total"]["samples"]}
        assert "type-conflict:umap_conflict" in errs


# ----------------------------------------------------------- PagerCollector


PAGER_COUNTERS = {
    "umap_pager_demand_faults_total", "umap_pager_page_hits_total",
    "umap_pager_wait_hits_total", "umap_pager_prefetch_fills_total",
    "umap_pager_prefetch_hits_total", "umap_pager_evictions_total",
    "umap_pager_writebacks_total", "umap_pager_watermark_flushes_total",
    "umap_pager_coalesced_fills_total", "umap_pager_coalesced_pages_total",
    "umap_pager_coalesced_writebacks_total",
    "umap_pager_writeback_pages_total", "umap_pager_fill_stalls_total",
    "umap_pager_lock_contended_total", "umap_pager_steals_total",
    "umap_pager_stolen_work_total", "umap_pager_io_errors_total",
    "umap_pager_writeback_errors_total",
    "umap_pager_quarantine_retries_total",
    "umap_pager_pattern_transitions_total",
    "umap_pager_tier_promotions_total", "umap_pager_tier_demotions_total",
    "umap_pager_tier_errors_total", "umap_pager_tier_cycles_total",
    "umap_pager_shard_demand_faults_total",
    "umap_pager_shard_lock_contended_total",
    "umap_pager_shard_fill_stalls_total",
    "umap_pager_filler_fills_total",
}
PAGER_GAUGES = {
    "umap_pager_shards", "umap_pager_fill_queue_peak",
    "umap_pager_dirty_ratio", "umap_pager_buffer_slots",
    "umap_pager_page_size_bytes",
    # quarantine population can shrink again on §17.4 re-post: gauges
    "umap_pager_quarantined_pages", "umap_pager_shard_quarantined_pages",
}


class TestPagerCollector:
    def test_exact_family_names_and_types(self):
        r = make_region(shards=4)
        try:
            for pno in range(8):
                r.read(pno * PS, 64)
            fams = families_of(PagerCollector(r.service, label="s"))
            assert set(fams) == PAGER_COUNTERS | PAGER_GAUGES
            for name in PAGER_COUNTERS:
                assert fams[name].kind == "counter", name
            for name in PAGER_GAUGES:
                assert fams[name].kind == "gauge", name
        finally:
            uunmap(r)

    def test_label_sets_shard_filler_source(self):
        r = make_region(shards=4)
        try:
            for pno in range(16):
                r.read(pno * PS, 64)
            fams = families_of(PagerCollector(r.service, label="svcX"))
            for fam in fams.values():
                for _, labels, _ in fam.samples:
                    assert labels["source"] == "svcX", fam.name
            shard_labels = {lab["shard"] for _, lab, _ in
                            fams["umap_pager_shard_demand_faults_total"].samples}
            assert shard_labels == {"0", "1", "2", "3"}
            sum_per_shard = sum(v for _, _, v in
                                fams["umap_pager_shard_demand_faults_total"].samples)
            agg = fams["umap_pager_demand_faults_total"].samples[0][2]
            assert agg == sum_per_shard == 16
            fill_sum = sum(v for _, _, v in
                           fams["umap_pager_filler_fills_total"].samples)
            assert fill_sum == 16
            assert fams["umap_pager_shards"].samples[0][2] == 4
            assert fams["umap_pager_page_size_bytes"].samples[0][2] == PS
        finally:
            uunmap(r)

    def test_counters_monotonic_across_scrapes(self):
        r = make_region(shards=2)
        try:
            col = PagerCollector(r.service, label="s")
            for pno in range(4):
                r.read(pno * PS, 64)
            first = {f.name: sum(v for *_, v in f.samples)
                     for f in col.collect() if f.kind == "counter"}
            for pno in range(4, 12):
                r.read(pno * PS, 64)
            r.write(0, np.full(32, 7, np.uint8))
            r.flush()
            second = {f.name: sum(v for *_, v in f.samples)
                      for f in col.collect() if f.kind == "counter"}
            assert set(first) == set(second)
            for name, v1 in first.items():
                assert second[name] >= v1, f"{name} went backwards"
            assert second["umap_pager_demand_faults_total"] == 12
            assert second["umap_pager_writebacks_total"] >= 1
        finally:
            uunmap(r)


# --------------------------------------------------------- TieringCollector


TIER_COUNTERS = {
    "umap_tier_promotions_total", "umap_tier_demotions_total",
    "umap_tier_migration_aborts_total", "umap_tier_read_bytes_total",
    "umap_tier_migration_write_bytes_total",
    "umap_tier_shadow_demotions_total", "umap_tier_failovers_total",
}
TIER_GAUGES = {
    "umap_tier_resident_extents", "umap_tier_free_slots",
    "umap_tier_slots", "umap_tier_utility", "umap_tier_latency_seconds",
    "umap_tier_dirty_extents", "umap_tier_pinned_fast_extents",
    "umap_tier_levels", "umap_tier_extent_size_bytes",
}
# families carrying one sample per chain level, labeled tier="0"..tier="N"
TIER_PER_LEVEL = {
    "umap_tier_resident_extents", "umap_tier_free_slots", "umap_tier_slots",
    "umap_tier_utility", "umap_tier_latency_seconds",
    "umap_tier_read_bytes_total", "umap_tier_promotions_total",
    "umap_tier_demotions_total", "umap_tier_migration_write_bytes_total",
}


class TestTieringCollector:
    def _store(self, npages=32):
        slow = HostArrayStore((np.arange(npages * PS) % 251).astype(np.uint8))
        fast = HostArrayStore(np.zeros(npages * PS // 4, np.uint8))
        return TieredStore(fast=fast, slow=slow, extent_size=4 * PS)

    def test_exact_family_names_and_types(self):
        fams = families_of(TieringCollector(self._store(), label="t"))
        assert set(fams) == TIER_COUNTERS | TIER_GAUGES
        for name in TIER_COUNTERS:
            assert fams[name].kind == "counter", name
        for name in TIER_GAUGES:
            assert fams[name].kind == "gauge", name
        for fam in fams.values():
            for _, lab, _ in fam.samples:
                assert lab["source"] == "t", fam.name
                if fam.name in TIER_PER_LEVEL:
                    assert lab["tier"] in {"0", "1"}, fam.name
                else:
                    assert "tier" not in lab, fam.name

    def test_per_level_tier_labels(self):
        """One family per metric, one sample per chain level — a two-tier
        store emits tier=0 (fast) and tier=1 (base) under the SAME family
        names a deeper chain uses."""
        fams = families_of(TieringCollector(self._store(), label="t"))
        for name in TIER_PER_LEVEL - {"umap_tier_latency_seconds"}:
            tiers = [lab["tier"] for _, lab, _ in fams[name].samples]
            assert tiers == ["0", "1"], name
        lat = {(lab["tier"], lab["op"]) for _, lab, _ in
               fams["umap_tier_latency_seconds"].samples}
        assert lat == {("0", "read"), ("0", "write"),
                       ("1", "read"), ("1", "write")}
        assert fams["umap_tier_levels"].samples[0][2] == 2

    def test_tracks_promotions_and_residency(self):
        store = self._store()
        col = TieringCollector(store, label="t")
        before = families_of(col)
        assert before["umap_tier_resident_extents"].samples[0][2] == 0
        buf = np.empty(PS, np.uint8)
        store.read_into(0, buf)                     # promote_on_read extent 0
        after = families_of(col)

        def tier0(fam):
            return [v for _, lab, v in fam.samples if lab["tier"] == "0"][0]

        def base(fam):
            return [v for _, lab, v in fam.samples if lab["tier"] == "1"][0]

        assert tier0(after["umap_tier_promotions_total"]) >= 1
        assert tier0(after["umap_tier_resident_extents"]) >= 1
        assert base(after["umap_tier_read_bytes_total"]) >= PS
        # staging the promote copy wrote one extent into the fast tier
        assert tier0(after["umap_tier_migration_write_bytes_total"]) \
            >= store.extent_size
        # the staging read sampled the base tier's latency EWMA
        lat = {(lab["tier"], lab["op"]): v for _, lab, v in
               after["umap_tier_latency_seconds"].samples}
        assert lat[("1", "read")] > 0.0

    def test_relaxed_tier_stats_matches_locked_when_quiescent(self):
        store = self._store()
        buf = np.empty(PS, np.uint8)
        store.read_into(4 * PS, buf)
        assert store.tier_stats(relaxed=True) == store.tier_stats()

    def test_store_register_telemetry_roundtrip(self):
        reg = TelemetryRegistry()
        store = self._store()
        name = store.register_telemetry(registry=reg, label="direct")
        assert name == "tiering:direct"
        assert "umap_tier_slots" in parse_exposition(reg.render())


# ----------------------------------------------------------- LeaseCollector


class _FakeKV:
    def __init__(self):
        self.n = 0

    def stats(self):
        return {"leases": 3 + self.n, "lease_blocked_evictions": 1,
                "leased_sequences": 2, "pages_used": 5, "pages_free": 3,
                "occupancy": 0.625, "page_bytes": 1 << 14, "sequences": 4,
                "cow_copies": 2, "shared_pages": 1, "shared_pages_mapped": 3,
                "auto_evicted_pages": 6, "host_lock_contended": 0,
                "phases": {1: "stream", 2: "stream", 3: "random"}}


class TestLeaseCollector:
    def test_service_lease_metrics(self):
        r = make_region()
        try:
            with r.lease(2):
                pass
            fams = families_of(LeaseCollector(service=r.service, label="L"))
            assert set(fams) == {"umap_leases_granted_total",
                                 "umap_leases_blocked_evictions_total"}
            assert fams["umap_leases_granted_total"].kind == "counter"
            assert fams["umap_leases_granted_total"].samples[0][2] == 1
        finally:
            uunmap(r)

    def test_kv_and_weight_source_metrics(self):
        class _FakeWeightSource:
            staging_copies = 17

        fams = families_of(LeaseCollector(
            kv=_FakeKV(), weight_source=_FakeWeightSource(), label="L"))
        assert set(fams) == {"umap_kv_leases_granted_total",
                             "umap_kv_lease_blocked_evictions_total",
                             "umap_kv_leased_sequences",
                             "umap_weight_staging_copies_total"}
        assert fams["umap_kv_leased_sequences"].kind == "gauge"
        assert fams["umap_kv_leases_granted_total"].samples[0][2] == 3
        assert fams["umap_weight_staging_copies_total"].samples[0][2] == 17

    def test_kv_counter_monotonic(self):
        kv = _FakeKV()
        col = LeaseCollector(kv=kv, label="L")
        v1 = families_of(col)["umap_kv_leases_granted_total"].samples[0][2]
        kv.n += 5
        v2 = families_of(col)["umap_kv_leases_granted_total"].samples[0][2]
        assert v2 == v1 + 5

    def test_empty_collector_yields_nothing(self):
        assert families_of(LeaseCollector(label="L")) == {}


# ----------------------------------------------------------- ServeCollector


class _FakeAllocator:
    def occupancy(self):
        return 0.5


class _FakeEngine:
    def __init__(self):
        self.stats = {"steps": 10, "prefills": 4, "evictions": 1,
                      "requeues": 1, "admission_pauses": 2,
                      "slo_deferrals": 3, "slo_misses": 1, "expired": 0,
                      "victim_evictions": 2, "cow_copies": 5,
                      "shared_pages_mapped": 9, "prefix_hits": 6,
                      "prefix_drops": 1, "peak_pages_used": 7,
                      "shed_requests": 2,
                      "per_tenant": {
                          "gold": {"prefills": 3, "evictions": 1,
                                   "requeues": 1, "admission_pauses": 0,
                                   "slo_deferrals": 2, "slo_misses": 1,
                                   "expired": 0, "shed_requests": 2,
                                   "finished": 3,
                                   "tokens_generated": 24},
                          "bronze": {"prefills": 1, "evictions": 0,
                                     "requeues": 0, "admission_pauses": 2,
                                     "slo_deferrals": 1, "slo_misses": 0,
                                     "expired": 0, "shed_requests": 0,
                                     "finished": 1,
                                     "tokens_generated": 8},
                      }}
        self.active = {1: object(), 2: object()}
        self.waiting = [object()]
        self.finished = [object(), object(), object()]
        self.allocator = _FakeAllocator()
        self.tenants = {"gold": object(), "bronze": object()}


class _FakeWeightPager:
    stats = {"fills": 12, "hits": 30, "waits": 2, "evictions": 8,
             "pattern_transitions": 1, "steals": 3}
    num_slots = 4


SERVE_ENGINE_FAMILIES = {
    "umap_serve_steps_total", "umap_serve_prefills_total",
    "umap_serve_evictions_total", "umap_serve_requeues_total",
    "umap_serve_admission_pauses_total", "umap_serve_active_requests",
    "umap_serve_waiting_requests", "umap_serve_finished_requests_total",
    "umap_serve_pool_occupancy_ratio",
    "umap_serve_slo_deferrals_total", "umap_serve_slo_misses_total",
    "umap_serve_expired_total", "umap_serve_victim_evictions_total",
    "umap_serve_cow_copies_total", "umap_serve_shared_pages_mapped_total",
    "umap_serve_prefix_hits_total", "umap_serve_prefix_drops_total",
    "umap_serve_peak_pages_used", "umap_serve_tenants",
    "umap_serve_shed_total", "umap_serve_paging_degraded",
}
SERVE_TENANT_FAMILIES = {
    "umap_serve_tenant_prefills_total", "umap_serve_tenant_evictions_total",
    "umap_serve_tenant_requeues_total",
    "umap_serve_tenant_admission_pauses_total",
    "umap_serve_tenant_slo_deferrals_total",
    "umap_serve_tenant_slo_misses_total", "umap_serve_tenant_expired_total",
    "umap_serve_tenant_shed_requests_total",
    "umap_serve_tenant_finished_total",
    "umap_serve_tenant_tokens_generated_total",
}
SERVE_KV_FAMILIES = {
    "umap_kv_pages_used", "umap_kv_pages_free", "umap_kv_occupancy_ratio",
    "umap_kv_sequences", "umap_kv_page_size_bytes",
    "umap_kv_auto_evicted_pages_total", "umap_kv_host_lock_contended_total",
    "umap_kv_cow_copies_total", "umap_kv_shared_pages",
    "umap_kv_shared_pages_mapped_total", "umap_kv_sequences_by_phase",
}
SERVE_WEIGHT_FAMILIES = {
    "umap_weight_fills_total", "umap_weight_hits_total",
    "umap_weight_waits_total", "umap_weight_evictions_total",
    "umap_weight_pattern_transitions_total", "umap_weight_steals_total",
    "umap_weight_slots",
}


class TestServeCollector:
    def test_engine_families(self):
        fams = families_of(ServeCollector(engine=_FakeEngine(), label="e"))
        assert set(fams) == SERVE_ENGINE_FAMILIES | SERVE_TENANT_FAMILIES
        assert fams["umap_serve_steps_total"].samples[0][2] == 10
        assert fams["umap_serve_active_requests"].samples[0][2] == 2
        assert fams["umap_serve_pool_occupancy_ratio"].samples[0][2] == 0.5

    def test_per_tenant_labels_match_stats(self):
        """Every per-tenant family carries one sample per tenant, labeled
        ``tenant=``, whose value equals the engine's stats dict entry —
        the same parity contract as aggregate == sum(per_shard)."""
        eng = _FakeEngine()
        fams = families_of(ServeCollector(engine=eng, label="e"))
        per = eng.stats["per_tenant"]
        for fam_name in SERVE_TENANT_FAMILIES:
            fam = fams[fam_name]
            key = fam_name[len("umap_serve_tenant_"):-len("_total")]
            got = {lab["tenant"]: v for _, lab, v in fam.samples}
            assert got == {t: float(st[key]) for t, st in per.items()}, \
                fam_name

    def test_kv_families_and_phase_label(self):
        fams = families_of(ServeCollector(kv=_FakeKV(), label="e"))
        assert set(fams) == SERVE_KV_FAMILIES
        phases = {lab["phase"]: v for _, lab, v in
                  fams["umap_kv_sequences_by_phase"].samples}
        assert phases == {"stream": 2, "random": 1}

    def test_weight_pager_families(self):
        fams = families_of(ServeCollector(weight_pager=_FakeWeightPager(),
                                          label="w"))
        assert set(fams) == SERVE_WEIGHT_FAMILIES
        assert fams["umap_weight_slots"].samples[0][2] == 4
        assert fams["umap_weight_steals_total"].samples[0][2] == 3

    def test_all_sources_compose(self):
        fams = families_of(ServeCollector(
            engine=_FakeEngine(), kv=_FakeKV(),
            weight_pager=_FakeWeightPager(), label="all"))
        assert set(fams) == (SERVE_ENGINE_FAMILIES | SERVE_TENANT_FAMILIES
                             | SERVE_KV_FAMILIES | SERVE_WEIGHT_FAMILIES)


# --------------------------------------------------------- ProcessCollector


class TestProcessCollector:
    def test_families_present_and_sane(self):
        fams = families_of(ProcessCollector(label="self"))
        assert "umap_process_threads" in fams
        assert "umap_process_cpu_seconds_total" in fams
        assert "umap_process_uptime_seconds" in fams
        assert fams["umap_process_threads"].samples[0][2] >= 1
        assert fams["umap_process_cpu_seconds_total"].kind == "counter"
        if "umap_process_resident_memory_bytes" in fams:   # procfs platforms
            assert fams["umap_process_resident_memory_bytes"].samples[0][2] > 0
        if "umap_process_open_fds" in fams:
            assert fams["umap_process_open_fds"].samples[0][2] >= 1


# ------------------------------------------------------------- opt-in hooks


class TestServiceRegistration:
    def test_register_unregister_lifecycle(self):
        reg = TelemetryRegistry()
        r = make_region(tiered=True)
        try:
            names = r.service.register_telemetry(registry=reg, label="svc")
            assert names == ["pager:svc", "leases:svc", "tiering:svc/r0"]
            # idempotent: second call reports the same registration
            assert r.service.register_telemetry(registry=reg) == names
            assert set(reg.collector_names()) == set(names)
        finally:
            uunmap(r)
        assert reg.collector_names() == []            # close() unregistered

    def test_tiered_region_registered_after_optin(self):
        reg = TelemetryRegistry()
        r = make_region(tiered=False)
        try:
            r.service.register_telemetry(registry=reg, label="svc")
            assert not any(n.startswith("tiering:")
                           for n in reg.collector_names())
            npages = 16
            fast = HostArrayStore(np.zeros(npages * PS, np.uint8))
            slow = HostArrayStore(np.zeros(4 * npages * PS, np.uint8))
            r2 = umap(TieredStore(fast=fast, slow=slow, extent_size=4 * PS),
                      service=r.service)
            try:
                tier_names = [n for n in reg.collector_names()
                              if n.startswith("tiering:")]
                assert tier_names == [f"tiering:svc/r{r2.region_id}"]
            finally:
                uunmap(r2)
        finally:
            uunmap(r)


# ------------------------------------------------------------- exporter e2e


class TestExporterE2E:
    def test_scrape_over_http_ephemeral_port(self):
        reg = TelemetryRegistry()
        r = make_region()
        exp = TelemetryExporter(registry=reg, port=0).start()
        try:
            reg.register(PagerCollector(r.service, label="s"))
            for pno in range(8):
                r.read(pno * PS, 64)
            assert exp.port != 0
            resp = urllib.request.urlopen(exp.url, timeout=5)
            assert resp.status == 200
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            fams = parse_exposition(resp.read().decode())
            assert fams["umap_pager_demand_faults_total"]["type"] == "counter"
            samples = fams["umap_pager_demand_faults_total"]["samples"]
            assert samples[0][2] == 8
            # counters move between scrapes
            for pno in range(8, 12):
                r.read(pno * PS, 64)
            fams2 = parse_exposition(
                urllib.request.urlopen(exp.url, timeout=5).read().decode())
            assert fams2["umap_pager_demand_faults_total"]["samples"][0][2] == 12
        finally:
            exp.close()
            uunmap(r)

    def test_index_and_404(self):
        exp = TelemetryExporter(registry=TelemetryRegistry(), port=0).start()
        try:
            base = f"http://127.0.0.1:{exp.port}"
            idx = urllib.request.urlopen(base + "/", timeout=5)
            assert idx.status == 200 and b"/metrics" in idx.read()
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(base + "/nope", timeout=5)
            assert ei.value.code == 404
        finally:
            exp.close()

    def test_close_stops_serving(self):
        exp = TelemetryExporter(registry=TelemetryRegistry(), port=0).start()
        url = exp.url
        exp.close()
        with pytest.raises(urllib.error.URLError):
            urllib.request.urlopen(url, timeout=1)


# -------------------------------------------------------- scrape-path rules


class TestScrapeNeverBlocks:
    def test_scrape_completes_while_all_shard_locks_held(self):
        """The acceptance rule made executable: with EVERY shard lock held
        by another thread (a worst-case fill/eviction convoy), a scrape
        still completes, because the collector path is lock-free."""
        reg = TelemetryRegistry()
        r = make_region(shards=4, tiered=True)
        try:
            reg.register(PagerCollector(r.service, label="s"))
            for region in r.service._regions.values():
                if region.tiered:
                    reg.register(TieringCollector(region.store, label="t"))
            for pno in range(8):
                r.read(pno * PS, 64)
            done = threading.Event()
            out = {}

            def scrape():
                out["text"] = reg.render()
                done.set()

            locks = [shard.lock for shard in r.service.shards]
            for lk in locks:
                lk.acquire()
            try:
                t = threading.Thread(target=scrape, daemon=True)
                t.start()
                assert done.wait(timeout=5.0), \
                    "scrape blocked on a shard lock"
            finally:
                for lk in locks:
                    lk.release()
            fams = parse_exposition(out["text"])
            assert fams["umap_pager_demand_faults_total"]["samples"][0][2] == 8
            assert "umap_tier_resident_extents" in fams
        finally:
            uunmap(r)

    def test_fault_storm_while_scraping(self):
        """Fault storm + concurrent scrape loop: reads stay byte-exact,
        every scrape completes, and afterwards the aggregate snapshot still
        sums the per-shard counters exactly (scraping perturbs nothing)."""
        npages, nthreads, buf_pages = 256, 4, 64
        data = (np.arange(npages * PS) % 251).astype(np.uint8)
        cfg = UMapConfig(page_size=PS, buffer_size=buf_pages * PS,
                         num_fillers=4, num_evictors=1, shards=4)
        r = umap(HostArrayStore(data), config=cfg)
        reg = TelemetryRegistry()
        reg.register(PagerCollector(r.service, label="s"))
        exp = TelemetryExporter(registry=reg, port=0).start()
        stop = threading.Event()
        errors = []
        scrapes = []

        def storm(seed):
            rng = np.random.default_rng(seed)
            try:
                for pno in rng.permutation(npages):
                    got = r.read(int(pno) * PS, 64)
                    want = data[int(pno) * PS:int(pno) * PS + 64]
                    if not np.array_equal(got, want):
                        errors.append(f"bad bytes at page {pno}")
            except Exception as e:                    # pragma: no cover
                errors.append(repr(e))

        def scraper():
            while not stop.is_set():
                try:
                    body = urllib.request.urlopen(exp.url, timeout=5).read()
                    scrapes.append(len(body))
                except Exception as e:                # pragma: no cover
                    errors.append(f"scrape: {e!r}")

        try:
            threads = [threading.Thread(target=storm, args=(i,), daemon=True)
                       for i in range(nthreads)]
            sc = threading.Thread(target=scraper, daemon=True)
            sc.start()
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=60)
            stop.set()
            sc.join(timeout=10)
            assert not errors, errors[:5]
            assert len(scrapes) >= 2, "scraper never completed a scrape"
            # scrape text is well-formed under concurrency
            fams = parse_exposition(
                urllib.request.urlopen(exp.url, timeout=5).read().decode())
            assert fams["umap_pager_demand_faults_total"]["samples"][0][2] > 0
            # parity unperturbed: aggregate == per-shard sums (quiescent)
            from repro.core.pager import _SHARD_COUNTERS
            st = r.service.stats.snapshot()
            for key in _SHARD_COUNTERS:
                assert st[key] == sum(s[key] for s in st["per_shard"]), key
            # every touch is classified fault/hit/wait; eviction pressure
            # means pages can be re-faulted, so >= the touch count
            assert st["demand_faults"] + st["page_hits"] + st["wait_hits"] \
                >= npages * nthreads
        finally:
            stop.set()
            exp.close()
            uunmap(r)


# ------------------------------------------------------------ env autostart


class TestEnvAutostart:
    def _free_port(self):
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
        s.close()
        return port

    def test_env_port_parsing(self):
        assert telemetry.env_port({}) == 0
        assert telemetry.env_port({"UMAP_TELEMETRY_PORT": ""}) == 0
        assert telemetry.env_port({"UMAP_TELEMETRY_PORT": "junk"}) == 0
        assert telemetry.env_port({"UMAP_TELEMETRY_PORT": "9100"}) == 9100

    def test_disabled_by_default(self, monkeypatch):
        monkeypatch.delenv("UMAP_TELEMETRY_PORT", raising=False)
        r = make_region()
        try:
            assert r.service._telemetry is None
            assert telemetry.start_from_env() is None
        finally:
            uunmap(r)

    def test_autostart_registers_and_serves(self, monkeypatch):
        port = self._free_port()
        monkeypatch.setenv("UMAP_TELEMETRY_PORT", str(port))
        r = make_region()
        try:
            assert r.service._telemetry is not None
            exp = telemetry.env_exporter()
            assert exp is not None and exp.port == port
            fams = parse_exposition(
                urllib.request.urlopen(exp.url, timeout=5).read().decode())
            assert "umap_pager_demand_faults_total" in fams
            assert "umap_process_threads" in fams      # process collector too
        finally:
            uunmap(r)
            telemetry.shutdown()
            telemetry.default_registry().clear()
        # service close() removed its collectors from the default registry
        assert not any(n.startswith("pager:")
                       for n in telemetry.default_registry().collector_names())


# ------------------------------------------------------ ResilienceCollector


RESILIENCE_COUNTERS = {
    "umap_resilience_retries_total",
    "umap_resilience_retries_ok_total",
    "umap_resilience_retry_exhausted_total",
    "umap_resilience_deadline_exceeded_total",
    "umap_resilience_permanent_errors_total",
    "umap_resilience_breaker_rejections_total",
    "umap_resilience_hedges_total",
    "umap_resilience_hedge_wins_total",
    "umap_resilience_checksum_failures_total",
    "umap_resilience_breaker_opens_total",
    "umap_resilience_breaker_half_opens_total",
    "umap_resilience_breaker_closes_total",
}
RESILIENCE_GAUGES = {
    "umap_resilience_breaker_state",
    "umap_resilience_degraded_seconds",
}


class TestResilienceCollector:
    def _resilient_store(self):
        from repro.core import ChaosStore, ResilientStore
        chaos = ChaosStore(
            HostArrayStore((np.arange(16 * PS) % 251).astype(np.uint8)),
            seed=3)
        from repro.core.resilient import CircuitBreaker, RetryPolicy
        rs = ResilientStore(
            chaos, policy=RetryPolicy(retries=2, backoff_s=1e-4,
                                      max_backoff_s=1e-3),
            breaker=CircuitBreaker(threshold=5, reset_s=60.0))
        return rs, chaos

    def test_exact_family_names_and_types(self):
        rs, _ = self._resilient_store()
        fams = families_of(ResilienceCollector(rs, label="s"))
        assert set(fams) == RESILIENCE_COUNTERS | RESILIENCE_GAUGES
        for name in RESILIENCE_COUNTERS:
            assert fams[name].kind == "counter", name
        for name in RESILIENCE_GAUGES:
            assert fams[name].kind == "gauge", name

    def test_values_track_store_stats(self):
        rs, chaos = self._resilient_store()
        chaos.fail_next("read", count=2)
        rs.read_into(0, np.empty(PS, np.uint8))      # two retries absorbed
        chaos.kill()
        for _ in range(3):                           # trip the breaker
            try:
                rs.read_into(0, np.empty(PS, np.uint8))
            except OSError:
                pass
        fams = families_of(ResilienceCollector(rs, label="s"))
        snap = rs.resilience_stats()
        # exact parity with the wrapper snapshot, plus the known landmarks
        for key, mname in (("retries", "umap_resilience_retries_total"),
                           ("retries_ok", "umap_resilience_retries_ok_total"),
                           ("exhausted",
                            "umap_resilience_retry_exhausted_total"),
                           ("breaker_rejections",
                            "umap_resilience_breaker_rejections_total"),
                           ("breaker_opens",
                            "umap_resilience_breaker_opens_total")):
            assert fams[mname].samples[0][2] == snap[key], (key, mname)
        assert snap["retries"] >= 2 and snap["retries_ok"] == 1
        assert snap["breaker_opens"] == 1
        assert fams["umap_resilience_breaker_state"].samples[0][2] == 2  # open
        for _, labels, _ in fams["umap_resilience_retries_total"].samples:
            assert labels["source"] == "s"
        chaos.revive()

    def test_autoregistered_for_resilient_flat_region(self):
        reg = TelemetryRegistry()
        r = make_region(resilient_io=True)
        try:
            names = r.service.register_telemetry(registry=reg, label="svc")
            res_names = [n for n in names if n.startswith("resilience:")]
            assert res_names == ["resilience:svc/r0"]
            text = telemetry.render_registry(reg) \
                if hasattr(telemetry, "render_registry") else reg.render()
            assert "umap_resilience_retries_total" in text
        finally:
            uunmap(r)
        assert reg.collector_names() == []           # close() unregistered

    def test_autoregistered_per_tier(self):
        reg = TelemetryRegistry()
        r = make_region(tiered=True, resilient_io=True)
        try:
            names = r.service.register_telemetry(registry=reg, label="svc")
            res_names = sorted(n for n in names if n.startswith("resilience:"))
            assert res_names == ["resilience:svc/r0/fast",
                                 "resilience:svc/r0/slow"]
        finally:
            uunmap(r)
