import time

import numpy as np
import pytest

from repro.core import (
    FileStore,
    HostArrayStore,
    MultiFileStore,
    RemoteStore,
    SyntheticStore,
)


def test_file_store_roundtrip(tmp_path):
    p = tmp_path / "blob.bin"
    st = FileStore(str(p), size=64 * 1024, create=True)
    payload = np.random.default_rng(0).integers(0, 256, 5000, dtype=np.uint8)
    st.write_from(1234, payload)
    out = np.empty(5000, np.uint8)
    st.read_into(1234, out)
    assert np.array_equal(out, payload)
    st.close()


def test_file_store_eof_zero_fill(tmp_path):
    p = tmp_path / "short.bin"
    st = FileStore(str(p), size=100, create=True)
    st.write_from(0, np.full(100, 9, np.uint8))
    buf = np.full(256, 7, np.uint8)
    got = st.read_into(0, buf)
    assert got == 100
    assert (buf[:100] == 9).all() and (buf[100:] == 0).all()
    st.close()


def test_multi_file_store_spans_extents(tmp_path):
    stores = []
    for i in range(3):
        s = FileStore(str(tmp_path / f"f{i}.bin"), size=1000, create=True)
        s.write_from(0, np.full(1000, i + 1, np.uint8))
        stores.append(s)
    # map [f0 bytes 100:600), [f1 all), [f2 bytes 0:500) contiguously
    mf = MultiFileStore([(stores[0], 100, 500), (stores[1], 0, 1000), (stores[2], 0, 500)])
    assert mf.size == 2000
    buf = np.empty(2000, np.uint8)
    mf.read_into(0, buf)
    assert (buf[:500] == 1).all() and (buf[500:1500] == 2).all() and (buf[1500:] == 3).all()
    # a read spanning the f0/f1 boundary (paper §6.4: one fault, many files)
    buf2 = np.empty(200, np.uint8)
    mf.read_into(400, buf2)
    assert (buf2[:100] == 1).all() and (buf2[100:] == 2).all()
    # write across a boundary and read back
    mf.write_from(450, np.full(100, 7, np.uint8))
    buf3 = np.empty(100, np.uint8)
    mf.read_into(450, buf3)
    assert (buf3 == 7).all()
    mf.close()


def test_remote_store_latency_model():
    inner = HostArrayStore(np.zeros(1 << 16, np.uint8))
    remote = RemoteStore(inner, latency_s=0.01, bandwidth_Bps=1e9)
    buf = np.empty(4096, np.uint8)
    t0 = time.perf_counter()
    remote.read_into(0, buf)
    assert time.perf_counter() - t0 >= 0.01


def test_synthetic_store_generator_and_overlay():
    def gen(offset, buf):
        idx = np.arange(offset, offset + buf.nbytes, dtype=np.uint64)
        buf[:] = (idx % 251).astype(np.uint8)

    st = SyntheticStore(size=1 << 20, generator=gen, overlay_page=4096)
    buf = np.empty(100, np.uint8)
    st.read_into(1000, buf)
    assert np.array_equal(buf, (np.arange(1000, 1100) % 251).astype(np.uint8))
    st.write_from(5000, np.full(100, 77, np.uint8))
    out = np.empty(300, np.uint8)
    st.read_into(4900, out)
    assert np.array_equal(out[:100], (np.arange(4900, 5000) % 251).astype(np.uint8))
    assert (out[100:200] == 77).all()
    assert np.array_equal(out[200:], (np.arange(5100, 5200) % 251).astype(np.uint8))


def test_store_stats_counting():
    st = HostArrayStore(np.zeros(8192, np.uint8))
    st.read_into(0, np.empty(1024, np.uint8))
    st.write_from(0, np.ones(512, np.uint8))
    assert st.bytes_read == 1024 and st.num_reads == 1
    assert st.bytes_written == 512 and st.num_writes == 1


# ------------------------------------------------- batched reads (DESIGN.md §9)


def test_file_store_batch_read_and_eof_tail(tmp_path):
    data = (np.arange(10000) % 251).astype(np.uint8)
    p = tmp_path / "batch.bin"
    data.tofile(p)
    st = FileStore(str(p))
    bufs = [np.empty(4096, np.uint8) for _ in range(3)]
    got = st.read_into_batch(0, bufs)
    cat = np.concatenate(bufs)
    assert got == 10000
    assert np.array_equal(cat[:10000], data)
    assert (cat[10000:] == 0).all()          # past-EOF zero-fill
    assert st.num_reads == 1                  # ONE preadv, not one per page


def test_multi_file_store_batch_spans_extents(tmp_path):
    a = (np.arange(8000) % 251).astype(np.uint8)
    b = (np.arange(6000) % 97).astype(np.uint8)
    pa, pb = tmp_path / "a.bin", tmp_path / "b.bin"
    a.tofile(pa)
    b.tofile(pb)
    sa, sb = FileStore(str(pa)), FileStore(str(pb))
    mfs = MultiFileStore([(sa, 1000, 5000), (sb, 500, 4000)])
    bufs = [np.empty(3000, np.uint8) for _ in range(3)]
    mfs.read_into_batch(0, bufs)
    expect = np.concatenate([a[1000:6000], b[500:4500]])
    assert np.array_equal(np.concatenate(bufs), expect)
    assert mfs.num_reads == 1                 # one extent walk


def test_remote_store_batch_pays_one_latency():
    inner = HostArrayStore(np.zeros(64 * 4096, np.uint8))
    remote = RemoteStore(inner, latency_s=0.01, bandwidth_Bps=1e12)
    bufs = [np.empty(4096, np.uint8) for _ in range(8)]
    t0 = time.perf_counter()
    remote.read_into_batch(0, bufs)
    dt = time.perf_counter() - t0
    assert dt < 8 * 0.01                      # one charge, not eight
    assert remote.num_reads == 1


def test_synthetic_store_batch_applies_overlay():
    st = SyntheticStore(1 << 16, lambda off, buf: buf.fill(7), overlay_page=4096)
    st.write_from(5000, np.full(100, 9, np.uint8))
    bufs = [np.empty(4096, np.uint8), np.empty(4096, np.uint8)]
    st.read_into_batch(4096, bufs)
    cat = np.concatenate(bufs)
    assert cat[5000 - 4096] == 9 and cat[0] == 7 and cat[5100 - 4096] == 7
    assert st.num_reads == 1


def test_base_batch_default_loops_read_into():
    class Minimal(HostArrayStore):
        # fall back to the ABC default by removing the vectorized override
        read_into_batch = __import__("repro.core.store", fromlist=["BackingStore"]
                                     ).BackingStore.read_into_batch

    st = Minimal((np.arange(16384) % 251).astype(np.uint8))
    bufs = [np.empty(4096, np.uint8) for _ in range(4)]
    st.read_into_batch(0, bufs)
    assert np.array_equal(np.concatenate(bufs),
                          (np.arange(16384) % 251).astype(np.uint8))
    assert st.num_reads == 4                  # honest: one call per buf
