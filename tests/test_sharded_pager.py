"""Sharded concurrency architecture (DESIGN.md §12).

Covers: shard configuration (auto heuristic, clamping, env parity,
mmap_compat pinning), the multi-threaded fault storm (no lost wakeups, no
double install, byte-exact reads under eviction pressure), work stealing
between filler deques, read/write decoupling (fillers never call
``write_from``), the ``flush_region(evict=True)`` vs concurrent-fill
regression, and per-shard stats aggregation.
"""

import threading

import numpy as np
import pytest

from repro.core import (
    HostArrayStore,
    PagingService,
    RemoteStore,
    SyntheticStore,
    UMapConfig,
    umap,
    uunmap,
)


def _pattern_gen(offset: int, buf: np.ndarray) -> None:
    """Deterministic synthetic contents: byte i of the space is (i % 251)."""
    n = buf.nbytes
    buf[:] = (np.arange(offset, offset + n, dtype=np.int64) % 251).astype(np.uint8)


# ------------------------------------------------------------- configuration


def test_shards_auto_heuristic_and_clamps():
    cfg = UMapConfig(page_size=4096, buffer_size=64 * 4096, num_fillers=4,
                     num_evictors=1)
    assert cfg.shards == 0
    assert cfg.effective_shards == 8          # min(16, 2*4), 64 slots available
    cfg = cfg.replace(num_fillers=32)
    assert cfg.effective_shards == 16         # capped at 16
    tiny = UMapConfig(page_size=4096, buffer_size=3 * 4096, num_fillers=8,
                      num_evictors=1)
    # clamped: stripes with <MIN_SLOTS_PER_SHARD slots would thrash their
    # private free lists, so a 3-slot buffer collapses to one stripe
    assert tiny.effective_shards == 1
    small = UMapConfig(page_size=4096, buffer_size=16 * 4096, shards=16,
                       num_evictors=1)
    assert small.effective_shards == 16 // UMapConfig.MIN_SLOTS_PER_SHARD
    explicit = UMapConfig(page_size=4096, buffer_size=64 * 4096, shards=5,
                          num_evictors=1)
    assert explicit.effective_shards == 5
    with pytest.raises(ValueError):
        UMapConfig(shards=-1)


def test_shards_env_parity():
    cfg = UMapConfig.from_env(env={"UMAP_SHARDS": "7",
                                   "UMAP_BUFSIZE": str(64 * 4096)})
    assert cfg.shards == 7 and cfg.effective_shards == 7


def test_mmap_compat_single_shard():
    cfg = UMapConfig.mmap_baseline(buffer_size=64 * 4096)
    assert cfg.effective_shards == 1
    r = umap(HostArrayStore(np.zeros(16 * 4096, np.uint8)), config=cfg)
    try:
        assert len(r.service.shards) == 1
        assert r.stats()["shards"] == 1
    finally:
        uunmap(r)


def test_service_instantiates_shards_with_disjoint_slots():
    cfg = UMapConfig(page_size=4096, buffer_size=64 * 4096, num_fillers=4,
                     num_evictors=1, shards=8)
    svc = PagingService(cfg)
    try:
        assert len(svc.shards) == 8
        all_slots = [s for shard in svc.shards for s in shard.free]
        assert sorted(all_slots) == list(range(64))      # disjoint, complete
        st = svc.stats
        assert st.shards == 8 and len(st.per_shard) == 8
        assert set(st.per_shard[0]) >= {"demand_faults", "lock_contended",
                                        "fill_stalls", "evictions"}
    finally:
        svc.close()


# --------------------------------------------------------------- fault storm


@pytest.mark.slow
def test_fault_storm_byte_exact_under_eviction_pressure():
    """N threads × random+strided faults: no lost wakeups, no double
    install, byte-exact reads, buffer invariants hold (satellite task)."""
    npages, ps, slots = 512, 4096, 64
    store = SyntheticStore(npages * ps, _pattern_gen)
    cfg = UMapConfig(page_size=ps, buffer_size=slots * ps, num_fillers=8,
                     num_evictors=2, shards=8)
    r = umap(store, config=cfg)
    errors = []
    barrier = threading.Barrier(8)

    def worker(seed):
        rng = np.random.default_rng(seed)
        barrier.wait()
        for i in range(250):
            if i % 3 == 0:                    # strided component
                pno = (seed * 37 + i * 7) % npages
            else:                             # random component
                pno = int(rng.integers(0, npages))
            off = pno * ps + int(rng.integers(0, ps - 64))
            got = r.read(off, 64)
            want = (np.arange(off, off + 64, dtype=np.int64) % 251).astype(np.uint8)
            if not np.array_equal(got, want):
                errors.append((pno, off))
                return

    try:
        ts = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
        [t.start() for t in ts]
        [t.join(timeout=60) for t in ts]
        assert not any(t.is_alive() for t in ts), "storm thread hung (lost wakeup?)"
        assert not errors, f"inconsistent reads at {errors[:5]}"
        st = r.stats()
        assert st["demand_faults"] > 0
        assert r.service.buffer.used_slots <= slots
        assert 0 <= r.service.table.dirty_count <= slots
    finally:
        uunmap(r)


def test_storm_mixed_writers_and_readers_consistent():
    """Writers own disjoint page ranges; readers verify; flush round-trips."""
    npages, ps = 64, 4096
    base = (np.arange(npages * ps) % 251).astype(np.uint8)
    store = HostArrayStore(base.copy())
    cfg = UMapConfig(page_size=ps, buffer_size=16 * ps, num_fillers=4,
                     num_evictors=2, shards=8,
                     evict_high_water=0.5, evict_low_water=0.25)
    r = umap(store, config=cfg)
    errors = []

    def writer(tid):
        lo = tid * 16                          # disjoint 16-page ranges
        for i in range(40):
            pno = lo + (i % 16)
            r.write(pno * ps, np.full(256, 100 + tid, np.uint8))

    def reader(tid):
        rng = np.random.default_rng(tid)
        for _ in range(80):
            pno = int(rng.integers(0, npages))
            got = r.read(pno * ps + 512, 64)    # offset 512: never written
            want = base[pno * ps + 512 : pno * ps + 576]
            if not np.array_equal(got, want):
                errors.append(pno)
                return

    try:
        ts = ([threading.Thread(target=writer, args=(t,)) for t in range(4)]
              + [threading.Thread(target=reader, args=(t,)) for t in range(4)])
        [t.start() for t in ts]
        [t.join(timeout=60) for t in ts]
        assert not any(t.is_alive() for t in ts), "mixed storm hung"
        assert not errors, f"reader saw torn data on pages {errors[:5]}"
        r.flush()
        for tid in range(4):
            chk = np.empty(256, np.uint8)
            store.read_into(tid * 16 * ps, chk)
            assert (chk == 100 + tid).all(), "write-back lost a dirty page"
    finally:
        uunmap(r)


# ------------------------------------------------------------- work stealing


def test_work_stealing_rebalances_one_hot_deque():
    """All fills route to one granule (one deque); with slow I/O the other
    fillers must steal — §3.3 dynamic load balancing as a protocol."""
    npages, ps = 64, 4096
    inner = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    store = RemoteStore(inner, latency_s=2e-3, bandwidth_Bps=1e9)
    store.batch_read_hint = 1                  # forbid coalescing: 64 singles
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=4,
                     num_evictors=1, max_batch_pages=64, shards=8)
    r = umap(store, config=cfg)
    try:
        # One granule (64 pages // max_batch_pages=64) => one routed deque.
        r.service.request_fills(r, list(range(npages)))
        for pno in range(npages):
            got = r.read(pno * ps, 64)
            assert got[0] == (pno * ps) % 251
        st = r.stats()
        assert st["steals"] >= 1, f"idle fillers never stole: {st}"
        assert st["stolen_work"] >= 1
        assert len(st["per_filler_fills"]) >= 2, \
            f"stealing engaged only one filler: {st['per_filler_fills']}"
    finally:
        uunmap(r)


def test_steal_preserves_coalescible_order():
    """Stolen runs stay in ascending order, so the thief can still batch."""
    npages, ps = 128, 4096
    inner = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    store = RemoteStore(inner, latency_s=1e-3, bandwidth_Bps=1e9)
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=4,
                     num_evictors=1, max_batch_pages=128, shards=8)
    r = umap(store, config=cfg)
    try:
        out = r.read(0, npages * ps)
        assert np.array_equal(
            out, (np.arange(npages * ps) % 251).astype(np.uint8))
        st = r.stats()
        assert st["coalesced_pages"] >= st["coalesced_fills"] >= 1
    finally:
        uunmap(r)


# ------------------------------------------------------ read/write decoupling


class _ThreadLoggingStore(HostArrayStore):
    """Records which thread issued every write_from (decoupling witness)."""

    def __init__(self, data):
        super().__init__(data)
        self.write_threads = []

    def write_from(self, offset, buf):
        self.write_threads.append(threading.current_thread().name)
        return super().write_from(offset, buf)


def test_fillers_never_write_dirty_pages_drain_via_cleaners():
    """A write-back burst must be served by evictors (cleaner queue), never
    by fillers — the decoupled write path (satellite task)."""
    npages, ps, slots = 64, 4096, 8
    store = _ThreadLoggingStore((np.arange(npages * ps) % 251).astype(np.uint8))
    cfg = UMapConfig(page_size=ps, buffer_size=slots * ps, num_fillers=4,
                     num_evictors=2, shards=4,
                     evict_high_water=0.9, evict_low_water=0.7)
    r = umap(store, config=cfg)
    try:
        # Dirty the whole buffer, then demand-fill past it: fillers need
        # slots whose only victims are dirty => cleaner backpressure.
        for pno in range(slots):
            r.write(pno * ps, np.full(ps, 7, np.uint8))
        for pno in range(slots, npages):
            got = r.read(pno * ps, 64)
            assert got[0] == (pno * ps) % 251
        st = r.stats()
        assert st["writebacks"] > 0, "no write-back happened at all"
        bad = [t for t in store.write_threads if t.startswith("umap-filler")]
        assert not bad, f"fillers performed write-back: {set(bad)}"
    finally:
        uunmap(r)
        # flush path (main thread) + evictors are the only legal writers
        legal = ("umap-evictor", "MainThread")
        assert all(t.startswith(legal) for t in store.write_threads), \
            set(store.write_threads)


def test_fill_stall_counter_reports_backpressure():
    npages, ps, slots = 32, 4096, 4
    inner = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    # Slow write-back: while the single evictor sleeps in write_from, a
    # demand fill with every slot dirty has no clean victim and MUST stall
    # on cleaner backpressure (instant write-back would let the eager
    # dirty-top cleaning hide the stall).
    store = RemoteStore(inner, latency_s=5e-3, bandwidth_Bps=1e9)
    cfg = UMapConfig(page_size=ps, buffer_size=slots * ps, num_fillers=2,
                     num_evictors=1, shards=1)
    r = umap(store, config=cfg)
    try:
        for pno in range(slots):
            r.write(pno * ps, np.full(ps, 9, np.uint8))
        for pno in range(slots, npages):
            got = r.read(pno * ps, 64)
            assert got[0] == (pno * ps) % 251
        st = r.stats()
        assert st["fill_stalls"] >= 1
        assert st["writebacks"] >= 1
    finally:
        uunmap(r)


# ------------------------------------------- flush/unregister race regression


def test_flush_evict_vs_concurrent_fills_leaves_no_ghost_pages():
    """Regression (satellite task): fills posted just before close must not
    re-install pages after the evicting flush — the seed leaked a ghost
    entry (and later a KeyError in the evictor) through this window."""
    npages, ps = 64, 4096
    for _ in range(5):
        inner = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
        store = RemoteStore(inner, latency_s=1e-3, bandwidth_Bps=1e9)
        cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=4,
                         num_evictors=2, shards=8)
        svc = PagingService(cfg)
        r = umap(store, service=svc)
        rid = r.region_id
        r.write(0, np.full(64, 5, np.uint8))          # something dirty
        svc.request_fills(r, list(range(npages)), demand=False)
        r.close()                                      # unregister mid-flight
        assert not svc.table.region_entries(rid), "ghost page survived close"
        # service must remain fully functional for other regions
        r2 = umap(HostArrayStore(np.full(8 * ps, 3, np.uint8)), service=svc)
        assert (r2.read(0, 64) == 3).all()
        r2.close()
        svc.close()


def test_acquire_during_close_raises_instead_of_reinstalling():
    npages, ps = 16, 4096
    store = HostArrayStore(np.zeros(npages * ps, np.uint8))
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=2,
                     num_evictors=1)
    r = umap(store, config=cfg)
    r.read(0, 64)
    r._closing = True            # what unregister sets before its flush
    with pytest.raises(RuntimeError, match="closing"):
        r.read(0, 64)
    r._closing = False
    uunmap(r)


# ----------------------------------------------------------- stats aggregation


def test_per_shard_counters_aggregate_in_snapshot():
    npages, ps = 256, 4096
    store = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    # 2x slot headroom: slots are hash-striped across shards, so a 1:1
    # slot:page ratio can overflow a hot stripe and evict (by design).
    cfg = UMapConfig(page_size=ps, buffer_size=2 * npages * ps, num_fillers=4,
                     num_evictors=1, shards=8)
    r = umap(store, config=cfg)
    try:
        for pno in range(npages):
            r.read(pno * ps, 64)
        for pno in range(npages):
            r.read(pno * ps, 64)               # second pass: page hits
        st = r.stats()
        assert st["shards"] == 8 and len(st["per_shard"]) == 8
        for key in ("demand_faults", "page_hits"):
            assert st[key] == sum(s[key] for s in st["per_shard"]), key
        # faults spread across stripes, not funneled through one
        assert sum(1 for s in st["per_shard"] if s["demand_faults"] > 0) >= 4
        assert st["page_hits"] >= npages
    finally:
        uunmap(r)


def test_snapshot_key_parity_between_aggregate_and_per_shard():
    """Every shard-owned counter must appear both in the aggregate snapshot
    and in every per_shard dict, and the aggregate must equal the per-shard
    sum — the guard against counter-drift regressions like the seed's
    outside-lock ``writebacks`` increment (satellite task).  New counters
    (leases, write-back coalescing) are covered automatically."""
    from repro.core.pager import _SHARD_COUNTERS

    npages, ps = 64, 4096
    store = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=2,
                     num_evictors=1, shards=8)
    r = umap(store, config=cfg)
    try:
        for pno in range(npages):
            r.read(pno * ps, 64)
        for pno in range(0, npages, 2):
            r.write(pno * ps, np.full(32, 5, np.uint8))
        with r.lease(1):
            pass
        r.flush()
        st = r.stats()
        assert set(_SHARD_COUNTERS) <= set(st), \
            f"aggregate missing {set(_SHARD_COUNTERS) - set(st)}"
        for s in st["per_shard"]:
            assert set(s) == set(_SHARD_COUNTERS), \
                f"per_shard keys drifted: {set(s) ^ set(_SHARD_COUNTERS)}"
        for key in _SHARD_COUNTERS:
            assert st[key] == sum(s[key] for s in st["per_shard"]), key
        # the new §13 counters are present on both sides
        for key in ("leases", "lease_blocked_evictions",
                    "coalesced_writebacks", "writeback_pages"):
            assert key in st and key in st["per_shard"][0]
        assert st["leases"] == 1
    finally:
        uunmap(r)


def test_snapshot_key_parity_covers_error_and_tier_counters():
    """Parity extension for the §14 counters (satellite task): the error /
    quarantine trio is shard-owned (aggregate == per-shard sum) and the
    tier-migration counters are service-owned (present in the aggregate,
    absent from per_shard) — so telemetry collectors can rely on the key
    placement, not just the key set."""
    from repro.core.pager import _SERVICE_COUNTERS, _SHARD_COUNTERS

    for key in ("io_errors", "writeback_errors", "quarantined_pages"):
        assert key in _SHARD_COUNTERS, key
    for key in ("tier_promotions", "tier_demotions", "tier_errors"):
        assert key in _SERVICE_COUNTERS, key

    npages, ps = 32, 4096
    store = HostArrayStore((np.arange(npages * ps) % 251).astype(np.uint8))
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=2,
                     num_evictors=1, shards=4)
    r = umap(store, config=cfg)
    try:
        for pno in range(npages):
            r.read(pno * ps, 64)
        st = r.stats()
        for key in ("io_errors", "writeback_errors", "quarantined_pages"):
            assert st[key] == sum(s[key] for s in st["per_shard"]), key
        for key in ("tier_promotions", "tier_demotions", "tier_errors"):
            assert key in st, key
            assert key not in st["per_shard"][0], key
    finally:
        uunmap(r)
