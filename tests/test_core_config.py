import pytest

from repro.core import UMapConfig, parse_size


def test_parse_size():
    assert parse_size(123) == 123
    assert parse_size("4096") == 4096
    assert parse_size("64K") == 64 * 1024
    assert parse_size("8M") == 8 * 1024**2
    assert parse_size("1GiB") == 1024**3
    assert parse_size("2kb") == 2048


def test_env_parity():
    env = {
        "UMAP_PAGESIZE": "512K",
        "UMAP_BUFSIZE": "64M",
        "UMAP_PAGE_FILLERS": "48",
        "UMAP_PAGE_EVICTORS": "24",
        "UMAP_EVICT_HIGH_WATER_THRESHOLD": "90",
        "UMAP_EVICT_LOW_WATER_THRESHOLD": "70",
        "UMAP_READ_AHEAD": "4",
        "UMAP_MAX_FAULT_EVENTS": "16",
    }
    cfg = UMapConfig.from_env(env)
    assert cfg.page_size == 512 * 1024
    assert cfg.buffer_size == 64 * 1024**2
    assert cfg.num_fillers == 48 and cfg.num_evictors == 24
    assert cfg.evict_high_water == pytest.approx(0.9)
    assert cfg.evict_low_water == pytest.approx(0.7)
    assert cfg.read_ahead == 4
    assert cfg.max_fault_events == 16
    assert cfg.num_slots == 128


def test_defaults_match_paper():
    cfg = UMapConfig()
    assert cfg.evict_high_water == pytest.approx(0.90)   # paper default 90%
    assert cfg.evict_low_water == pytest.approx(0.70)    # paper default 70%
    assert cfg.read_ahead == 0                           # paper default 0


def test_validation():
    with pytest.raises(ValueError):
        UMapConfig(page_size=0)
    with pytest.raises(ValueError):
        UMapConfig(page_size=8192, buffer_size=4096)
    with pytest.raises(ValueError):
        UMapConfig(evict_high_water=0.5, evict_low_water=0.9)
    with pytest.raises(ValueError):
        UMapConfig(num_fillers=0)


def test_mmap_baseline_semantics():
    cfg = UMapConfig.mmap_baseline(buffer_size=1 << 20)
    assert cfg.page_size == 4096          # fixed kernel page
    assert cfg.mmap_compat
    assert cfg.evict_high_water == pytest.approx(0.10)  # RHEL 10%-dirty flush
