"""N-tier chain semantics (DESIGN.md §14): spec parsing, per-level
residency with non-exclusive shadow copies, write-invalidation, online
latency sampling, per-level circuit-breaker route-around, target-level
hints through the region API, the deprecated two-knob env shim, and the
in-flight-write migration race the shared commit predicate must catch.
"""

import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import UMapConfig, umap, uunmap
from repro.core.store import (
    HostArrayStore,
    RemoteStore,
    TierChain,
    TieredStore,
    build_tier_stores,
    parse_tier_chain,
)

PS = 4096
EXT = 4 * PS


def _chain(npages=32, fast_exts=2, mid_exts=4, **kw):
    """host fast + host mid caches over a patterned host base tier."""
    data = (np.arange(npages * PS) % 251).astype(np.uint8)
    kw.setdefault("promote_on_read", False)
    tc = TierChain(
        [HostArrayStore(np.zeros(fast_exts * EXT, np.uint8)),
         HostArrayStore(np.zeros(mid_exts * EXT, np.uint8)),
         HostArrayStore(data)],
        extent_size=EXT,
        budgets=[fast_exts * EXT, mid_exts * EXT], **kw)
    return tc, data


def _read(tc, ext):
    buf = np.empty(EXT, np.uint8)
    tc.read_into(ext * EXT, buf)
    return buf


def _wait(predicate, timeout=10.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {msg}")


# ------------------------------------------------------------- spec parsing


class TestChainSpec:
    def test_host_and_file_levels_with_suffixes(self, tmp_path):
        spec = f"host:8M, file:{tmp_path}/mid.bin:64K ,host:1g"
        levels = parse_tier_chain(spec)
        assert levels == [("host", (8 << 20,)),
                          ("file", (f"{tmp_path}/mid.bin", 64 << 10)),
                          ("host", (1 << 30,))]

    def test_spec_carries_no_latency_figures(self):
        # The grammar has nowhere to declare a tier speed: any extra
        # colon-separated field is rejected.  Latency is sampled online.
        with pytest.raises(ValueError):
            parse_tier_chain("host:8M:5ms")

    @pytest.mark.parametrize("bad", ["", " , ", "host", "gpu:8M",
                                     "file:/tmp/x", "host:0"])
    def test_bad_specs_raise(self, bad):
        with pytest.raises(ValueError):
            parse_tier_chain(bad)

    def test_build_tier_stores(self, tmp_path):
        spec = f"host:{EXT},file:{tmp_path}/t.bin:{2 * EXT}"
        stores = build_tier_stores(spec)
        assert [s.size for s in stores] == [EXT, 2 * EXT]
        stores[1].write_from(0, np.full(PS, 7, np.uint8))
        got = np.empty(PS, np.uint8)
        stores[1].read_into(0, got)
        assert np.array_equal(got, np.full(PS, 7, np.uint8))

    def test_from_config_builds_chain(self):
        cfg = UMapConfig(tier_chain=f"host:{2 * EXT},host:{4 * EXT}",
                         tier_extent_size=EXT)
        tc = TierChain.from_config(
            HostArrayStore(np.zeros(32 * PS, np.uint8)), cfg)
        assert tc.base_level == 2 and len(tc.levels) == 3
        assert tc.extent_size == EXT
        assert tc.free_slots(0) == 2 and tc.free_slots(1) == 4


# ------------------------------------------------ residency + shadow copies


class TestShadowResidency:
    def test_promote_up_chain_reads_from_fastest(self):
        tc, data = _chain()
        assert tc.promote(3, level=1) and tc.promote(3, level=0)
        # non-exclusive: BOTH cache levels hold a valid copy
        assert 3 in tc.resident_extents(0) and 3 in tc.resident_extents(1)
        assert tc.extent_level(3) == 0
        before = tc.tier_stats()["read_bytes_by_level"]
        assert np.array_equal(_read(tc, 3), data[3 * EXT:4 * EXT])
        after = tc.tier_stats()["read_bytes_by_level"]
        assert after[0] - before[0] == EXT          # served by the fast copy
        assert after[1] == before[1] and after[2] == before[2]

    def test_clean_demote_is_residency_flip(self):
        tc, data = _chain()
        tc.promote(2, level=1)
        tc.promote(2, level=0)
        wrote = tc.tier_stats()["migration_write_bytes_by_level"]
        assert tc.demote(2, level=0)
        stats = tc.tier_stats()
        assert stats["shadow_demotions"] == 1
        # the flip moved NO bytes anywhere
        assert stats["migration_write_bytes_by_level"] == wrote
        assert 2 not in tc.resident_extents(0) and 2 in tc.resident_extents(1)
        assert np.array_equal(_read(tc, 2), data[2 * EXT:3 * EXT])

    def test_copy_on_demote_baseline_always_writes_back(self):
        tc, _ = _chain(copy_on_demote=True)
        tc.promote(2, level=1)
        tc.promote(2, level=0)
        base_wrote = tc.tier_stats()["migration_write_bytes_by_level"][2]
        assert tc.demote(2, level=0)
        stats = tc.tier_stats()
        assert stats["shadow_demotions"] == 0
        assert stats["migration_write_bytes_by_level"][2] == base_wrote + EXT

    def test_write_invalidates_other_copies(self):
        tc, data = _chain()
        tc.promote(1, level=1)
        tc.promote(1, level=0)
        new = np.full(EXT, 9, np.uint8)
        tc.write_from(1 * EXT, new)
        # the write landed in the fastest copy and killed the others
        assert 1 in tc.resident_extents(0)
        assert 1 not in tc.resident_extents(1)
        assert np.array_equal(_read(tc, 1), new)
        # demoting the now-sole dirty copy must write back, not flip
        assert tc.demote(1, level=0)
        assert tc.tier_stats()["shadow_demotions"] == 0
        assert np.array_equal(_read(tc, 1), new)     # served by base now
        got = np.empty(EXT, np.uint8)
        tc.levels[-1].read_into(1 * EXT, got)
        assert np.array_equal(got, new)

    def test_budget_never_exceeded(self):
        tc, data = _chain(fast_exts=2, mid_exts=3)
        for ext in range(6):
            tc.promote(ext, level=1)
            tc.promote(ext, level=0)
        stats = tc.tier_stats()
        assert stats["resident_by_level"][0] <= 2
        assert stats["resident_by_level"][1] <= 3
        for ext in range(8):
            assert np.array_equal(_read(tc, ext),
                                  data[ext * EXT:(ext + 1) * EXT])


# ------------------------------------------------------- latency calibration


class TestLatencySampling:
    def test_unsampled_levels_read_zero(self):
        tc, _ = _chain()
        for lvl in range(3):
            assert tc.sampled_latency(lvl, "read") == 0.0
            assert tc.sampled_latency(lvl, "write") == 0.0

    def test_sampler_orders_tiers_by_observed_latency(self):
        data = (np.arange(16 * PS) % 251).astype(np.uint8)
        tc = TierChain(
            [HostArrayStore(np.zeros(2 * EXT, np.uint8)),
             RemoteStore(HostArrayStore(np.zeros(4 * EXT, np.uint8)),
                         latency_s=2e-3),
             RemoteStore(HostArrayStore(data), latency_s=8e-3)],
            extent_size=EXT, budgets=[2 * EXT, 4 * EXT],
            promote_on_read=False)
        tc.promote(0, level=1)
        tc.promote(0, level=0)
        for _ in range(3):
            _read(tc, 0)                 # fast reads
            _read(tc, 1)                 # base reads
        r0 = tc.sampled_latency(0, "read")
        r1 = tc.sampled_latency(1, "read")
        r2 = tc.sampled_latency(2, "read")
        assert 0.0 < r0 < r1 < r2
        assert r1 >= 2e-3 and r2 >= 8e-3
        stats = tc.tier_stats()
        assert stats["latency_read_s"] == [r0, r1, r2]

    def test_ewma_converges_not_jumps(self):
        tc, _ = _chain(ewma_alpha=0.5)
        tc._note_latency(0, 0, 1.0)
        tc._note_latency(0, 0, 0.0)
        assert tc.sampled_latency(0, "read") == pytest.approx(0.5)


# -------------------------------------------------- per-level breaker routing


class _StubBreaker:
    def __init__(self):
        self.down = False

    def tripped(self):
        return self.down


class _BreakeredStore(HostArrayStore):
    """HostArrayStore carrying a breaker the chain's router duck-types."""

    def __init__(self, arr):
        super().__init__(arr)
        self.breaker = _StubBreaker()


class TestMidTierBreaker:
    def test_tripped_middle_tier_routes_around(self):
        data = (np.arange(64 * PS) % 251).astype(np.uint8)
        mid = _BreakeredStore(np.zeros(4 * EXT, np.uint8))
        tc = TierChain(
            [HostArrayStore(np.zeros(2 * EXT, np.uint8)), mid,
             HostArrayStore(data)],
            extent_size=EXT, budgets=[2 * EXT, 4 * EXT],
            promote_on_read=False)
        tc.promote(0, level=1)               # copy lives ONLY at mid
        mid.breaker.down = True
        mid_reads = mid.stats["read_ops"] if hasattr(mid, "stats") else None
        before = tc.tier_stats()["read_bytes_by_level"]
        assert np.array_equal(_read(tc, 0), data[:EXT])
        after = tc.tier_stats()["read_bytes_by_level"]
        assert after[1] == before[1]          # tripped tier untouched
        assert after[2] - before[2] == EXT    # routed around to base
        assert tc.tier_stats()["tier_failovers"] >= 1
        # new promotions refuse the downed level outright
        assert not tc.promote(5, level=1)
        # recovery: breaker closes, the tier serves again
        mid.breaker.down = False
        tc.promote(5, level=1)
        assert 5 in tc.resident_extents(1)

    def test_sole_copy_on_tripped_tier_still_served(self):
        # A dirty extent whose ONLY copy sits on the tripped level must
        # keep routing to it — silently serving stale base bytes is worse
        # than a slow/failing read.
        mid = _BreakeredStore(np.zeros(4 * EXT, np.uint8))
        data = (np.arange(16 * PS) % 251).astype(np.uint8)
        tc = TierChain(
            [HostArrayStore(np.zeros(2 * EXT, np.uint8)), mid,
             HostArrayStore(data)],
            extent_size=EXT, budgets=[2 * EXT, 4 * EXT],
            promote_on_read=False)
        tc.promote(0, level=1)
        new = np.full(EXT, 3, np.uint8)
        tc.write_from(0, new)                 # dirty at mid, base stale
        mid.breaker.down = True
        assert np.array_equal(_read(tc, 0), new)


# -------------------------------------------------- migration race (shared
# commit predicate regression: in-flight write vs. staged promote)


class TestMigrationRace:
    def test_promote_aborts_on_inflight_write(self):
        tc, data = _chain()
        started = threading.Event()
        finish = threading.Event()
        orig = tc.levels[-1].read_into

        def slow_read(offset, buf):
            n = orig(offset, buf)
            started.set()
            assert finish.wait(5.0)
            return n

        tc.levels[-1].read_into = slow_read
        t = threading.Thread(target=tc.promote, args=(0,), daemon=True)
        t.start()
        assert started.wait(5.0)
        new = np.full(EXT, 5, np.uint8)
        w = threading.Thread(target=tc.write_from, args=(0, new), daemon=True)
        w.start()
        time.sleep(0.05)                      # writer bumps gen before I/O
        finish.set()
        t.join(5.0)
        w.join(5.0)
        tc.levels[-1].read_into = orig
        assert tc.tier_stats()["migration_aborts"] >= 1
        assert 0 not in tc.resident_extents(0)      # stale copy not published
        assert tc.free_slots(0) == 2                # staged slot returned
        assert np.array_equal(_read(tc, 0), new)
        assert tc.promote(0) is True                # engine survives


# -------------------------------------------------------- target-level hints


def _chain_region(npages=64, fast_exts=2, mid_exts=4, **cfg_kw):
    data = (np.arange(npages * PS) % 251).astype(np.uint8)
    tc = TierChain(
        [HostArrayStore(np.zeros(fast_exts * EXT, np.uint8)),
         HostArrayStore(np.zeros(mid_exts * EXT, np.uint8)),
         HostArrayStore(data)],
        extent_size=EXT, budgets=[fast_exts * EXT, mid_exts * EXT],
        promote_on_read=False)
    cfg_kw.setdefault("tier_interval_s", 0.05)
    cfg_kw.setdefault("tier_promote_heat", 2.0)
    cfg = UMapConfig(page_size=PS, buffer_size=16 * PS, num_fillers=2,
                     num_evictors=1, shards=2, **cfg_kw)
    return umap(tc, config=cfg), tc, data


class TestTargetLevelHints:
    def test_hot_level_hint_lands_mid_chain(self):
        r, tc, data = _chain_region()
        try:
            r.advise(tier_hint="hot:1", offset=3 * EXT, nbytes=2 * EXT)
            _wait(lambda: {3, 4} <= set(tc.resident_extents(1)),
                  msg="hot:1 extents at level 1")
            assert 3 not in tc.resident_extents(0)
            assert 4 not in tc.resident_extents(0)
            got = r.read(3 * EXT, EXT)
            assert np.array_equal(got, data[3 * EXT:4 * EXT])
        finally:
            uunmap(r)

    def test_pin_fast_level_hint_pins_and_holds(self):
        r, tc, data = _chain_region()
        try:
            r.advise(tier_hint="pin_fast:1", offset=0, nbytes=EXT)
            _wait(lambda: 0 in tc.resident_extents(1),
                  msg="pinned extent at level 1")
            assert tc.pin_levels() == {0: 1}
            # a demote that would strand the pin below its ceiling refuses
            assert not tc.demote(0, level=1)
        finally:
            uunmap(r)

    def test_bad_level_hint_raises(self):
        r, tc, _ = _chain_region()
        try:
            with pytest.raises(ValueError):
                r.advise(tier_hint="hot:9", offset=0, nbytes=EXT)
            with pytest.raises(ValueError):
                r.advise(tier_hint="cold:1", offset=0, nbytes=EXT)
            with pytest.raises(ValueError):
                r.advise(tier_hint="hot:x", offset=0, nbytes=EXT)
        finally:
            uunmap(r)


# --------------------------------------------------------------- env shim


class TestDeprecatedEnvShim:
    def test_fast_bytes_env_maps_to_depth2_chain(self):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            cfg = UMapConfig.from_env(env={
                "UMAP_TIER_FAST_BYTES": str(4 * EXT)})
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        assert cfg.tier_chain == f"host:{4 * EXT}"
        assert cfg.tier_fast_bytes == 4 * EXT

    def test_explicit_chain_spec_wins_over_legacy_knob(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")   # no deprecation when both set
            cfg = UMapConfig.from_env(env={
                "UMAP_TIER_CHAIN": f"host:{2 * EXT}",
                "UMAP_TIER_FAST_BYTES": str(4 * EXT)})
        assert cfg.tier_chain == f"host:{2 * EXT}"

    def test_legacy_env_behaves_byte_identically(self):
        """The shimmed depth-2 chain serves the same bytes with the same
        migration behavior as the legacy two-knob TieredStore."""
        npages = 32
        data = (np.arange(npages * PS) % 251).astype(np.uint8)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            cfg = UMapConfig.from_env(env={
                "UMAP_TIER_FAST_BYTES": str(2 * EXT),
                "UMAP_TIER_EXTENT": str(EXT)})
        legacy = TieredStore.from_config(HostArrayStore(data.copy()),
                                         UMapConfig(tier_fast_bytes=2 * EXT,
                                                    tier_extent_size=EXT))
        shimmed = TierChain.from_config(HostArrayStore(data.copy()), cfg)
        assert shimmed.extent_size == legacy.extent_size == EXT
        assert shimmed.num_fast_slots == legacy.num_fast_slots == 2
        assert shimmed.base_level == legacy.base_level == 1
        for ts in (legacy, shimmed):
            assert ts.promote(1) and ts.promote(3)
            new = np.full(EXT, 11, np.uint8)
            ts.write_from(3 * EXT, new)
            assert ts.demote(1)
        for ext in range(8):
            want = (np.full(EXT, 11, np.uint8) if ext == 3
                    else data[ext * EXT:(ext + 1) * EXT])
            a = np.empty(EXT, np.uint8)
            b = np.empty(EXT, np.uint8)
            legacy.read_into(ext * EXT, a)
            shimmed.read_into(ext * EXT, b)
            assert np.array_equal(a, want) and np.array_equal(b, want), ext
        ls, ss = legacy.tier_stats(), shimmed.tier_stats()
        for key in ("resident_extents", "free_fast_slots", "dirty_extents",
                    "promotions", "demotions", "resident_by_level",
                    "slots_by_level", "free_by_level"):
            assert ls[key] == ss[key], key
