"""Zero-copy leases + coalesced write-back pipeline (DESIGN.md §13).

Covers: the lease life-cycle (zero-copy aliasing, read-only read leases,
dirty-exactly-once write leases, idempotent release, pin-blocks-eviction),
``lease_run`` length caps and cleanup-on-error, the copy-backed
``zero_copy_leases=False`` mode, the concurrent-lease vs
``flush_region(evict=True)`` closing-gate interaction, the
pinned-at-dequeue cleaner regression (satellite fix), write-back
coalescing counters, ``write_from_batch`` byte-exactness across all five
stores, and the zero-staging-copy witnesses for the converted consumers
(weight pager + paged KV).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    FileStore,
    HostArrayStore,
    MultiFileStore,
    PageState,
    RemoteStore,
    SyntheticStore,
    UMapConfig,
    umap,
    uunmap,
)


def _pattern(n: int, offset: int = 0) -> np.ndarray:
    return (np.arange(offset, offset + n, dtype=np.int64) % 251).astype(np.uint8)


def _make_region(npages=32, ps=4096, slots=None, **cfg_kw):
    store = HostArrayStore(_pattern(npages * ps).copy())
    cfg = UMapConfig(page_size=ps, buffer_size=(slots or npages) * ps,
                     num_fillers=2, num_evictors=2, shards=4, **cfg_kw)
    return store, umap(store, config=cfg)


# ------------------------------------------------------------ lease life-cycle


def test_read_lease_is_zero_copy_and_read_only():
    ps = 4096
    store, r = _make_region(ps=ps)
    try:
        with r.lease(3) as ls:
            assert np.array_equal(ls.view, _pattern(ps, 3 * ps))
            assert ls.zero_copy
            with pytest.raises(ValueError):
                ls.view[0] = 1                   # read lease: immutable view
            # genuinely aliases the buffer slot — no memcpy happened
            e = r.service.table.get((r.region_id, 3))
            slot = r.service.buffer.slot_view(e.slot, ps)
            assert np.shares_memory(ls.view, slot)
        assert r.stats()["leases"] == 1
    finally:
        uunmap(r)


def test_write_lease_marks_dirty_exactly_once_and_release_is_idempotent():
    ps = 4096
    store, r = _make_region(ps=ps)
    try:
        before = r.service.table.dirty_count
        ls = r.lease(2, write=True)
        ls.view[:64] = 77
        assert r.service.table.dirty_count == before  # dirty only on release
        ls.release()
        assert r.service.table.dirty_count == before + 1
        ls.release()                                  # idempotent
        ls.release()
        assert r.service.table.dirty_count == before + 1
        e = r.service.table.get((r.region_id, 2))
        assert e.pins == 0 and e.leases == 0
        r.flush()
        chk = np.empty(64, np.uint8)
        store.read_into(2 * ps, chk)
        assert (chk == 77).all()
    finally:
        uunmap(r)


def test_lease_pin_blocks_eviction_and_is_counted():
    """A leased page must survive arbitrary capacity churn; the skipped
    victim picks surface as lease_blocked_evictions."""
    npages, ps, slots = 64, 4096, 8
    store, r = _make_region(npages=npages, ps=ps, slots=slots)
    try:
        with r.lease(0) as ls:
            for pno in range(1, npages):          # storm past the buffer
                assert r.read(pno * ps, 64)[0] == _pattern(1, pno * ps)[0]
            # still resident, still byte-exact, never recycled
            e = r.service.table.get((r.region_id, 0))
            assert e is not None and e.state is PageState.PRESENT
            assert np.array_equal(ls.view, _pattern(ps))
        st = r.stats()
        assert st["lease_blocked_evictions"] >= 1
        assert st["evictions"] > 0                # churn really happened
    finally:
        uunmap(r)


def test_lease_run_posts_fills_and_caps_length():
    npages, ps = 32, 4096
    store, r = _make_region(npages=npages, ps=ps)
    try:
        with r.lease_run(4, 6) as run:
            assert len(run) == 6
            for i, v in enumerate(run.views):
                assert np.array_equal(v, _pattern(ps, (4 + i) * ps))
        cap = min(r.service.config.max_lease_run,
                  r.service.buffer.num_slots // 2)
        with pytest.raises(ValueError):
            r.service.lease_run(r, 0, cap + 1)
        with pytest.raises(IndexError):
            r.lease_run(npages - 2, 4)            # falls off the region
        assert r.stats()["leases"] == 6
    finally:
        uunmap(r)


def test_copy_backed_mode_keeps_lease_api_without_aliasing():
    ps = 4096
    store, r = _make_region(ps=ps, zero_copy_leases=False)
    try:
        with r.lease(1) as ls:
            assert not ls.zero_copy
            assert np.array_equal(ls.view, _pattern(ps, ps))
        with r.lease(1, write=True) as ls:
            ls.view[:32] = 55
        assert (r.read(ps, 32) == 55).all()       # written back on release
        assert r.stats()["leases"] == 2
    finally:
        uunmap(r)


def test_concurrent_lease_vs_evicting_flush_closing_gate():
    """Leases racing region close: either the lease wins (and close waits
    for its pin) or the closing gate raises — never a ghost page, never a
    view into a recycled slot."""
    npages, ps = 16, 4096
    for _ in range(5):
        store = HostArrayStore(_pattern(npages * ps).copy())
        cfg = UMapConfig(page_size=ps, buffer_size=npages * ps,
                         num_fillers=2, num_evictors=2, shards=4)
        from repro.core import PagingService
        svc = PagingService(cfg)
        r = umap(store, service=svc)
        rid = r.region_id
        stop = threading.Event()
        raised = []

        def leaser():
            rng = np.random.default_rng(0)
            while not stop.is_set():
                pno = int(rng.integers(0, npages))
                try:
                    with r.lease(pno) as ls:
                        assert ls.view[0] == _pattern(1, pno * ps)[0]
                except RuntimeError as exc:       # closing gate
                    raised.append(str(exc))
                    return

        ts = [threading.Thread(target=leaser) for _ in range(3)]
        [t.start() for t in ts]
        time.sleep(0.01)
        r.close()                                  # evicting flush + unregister
        stop.set()
        [t.join(timeout=30) for t in ts]
        assert not any(t.is_alive() for t in ts), "leaser hung against close"
        assert all("closing" in m for m in raised)
        assert not svc.table.region_entries(rid), "ghost page survived close"
        svc.close()


# --------------------------------------------- pinned-at-dequeue (satellite fix)


def test_cleaner_refuses_page_pinned_after_posting():
    """Regression: a page posted to the cleaner queue and *then* pinned
    (an in-flight lease) must not be written back mid-mutation — the
    evictor re-checks pins at dequeue time, reverts the page to PRESENT,
    and leaves it dirty for a later repost."""
    ps = 4096
    store, r = _make_region(ps=ps)
    svc = r.service
    try:
        r.write(0, np.full(ps, 9, np.uint8))       # page 0 resident + dirty
        key = (r.region_id, 0)
        e = svc.table.get(key)
        ls = r.lease(0, write=True)
        ls.view[:16] = 123                          # mid-mutation
        writes_before = store.num_writes
        # Simulate the racing poster: CLEANING + queued while pinned (the
        # in-tree posters check pins at post time; the dequeue-time check
        # is the defense for any interleaving that slips past them).
        shard = svc._shard_of(key)
        with svc._locked(shard):
            e.state = PageState.CLEANING
            e.event.clear()
            svc._clean_q.put(("clean", e))
        deadline = time.time() + 5.0
        while e.state is PageState.CLEANING and time.time() < deadline:
            time.sleep(0.001)
        assert e.state is PageState.PRESENT, "cleaner never handled the page"
        assert store.num_writes == writes_before, \
            "cleaner wrote back a lease-pinned page mid-mutation"
        assert e.dirty, "dirty bit lost on the deferred page"
        assert r.stats()["lease_blocked_evictions"] >= 1
        ls.release()
        r.flush()                                   # now it may drain
        chk = np.empty(16, np.uint8)
        store.read_into(0, chk)
        assert (chk == 123).all()
    finally:
        uunmap(r)


# ------------------------------------------------------- write-back coalescing


def test_flush_coalesces_adjacent_dirty_pages():
    npages, ps = 32, 4096
    store, r = _make_region(npages=npages, ps=ps)
    try:
        for pno in range(8):
            r.write(pno * ps, np.full(ps, 7, np.uint8))
        writes_before = store.num_writes
        r.flush()
        st = r.stats()
        assert store.num_writes - writes_before < 8, \
            "flush issued one store write per dirty page"
        assert st["coalesced_writebacks"] >= 1
        assert st["writeback_pages"] >= 8
        assert st["writebacks"] == 8               # per-page accounting intact
        chk = np.empty(8 * ps, np.uint8)
        store.read_into(0, chk)
        assert (chk == 7).all()
    finally:
        uunmap(r)


def test_max_writeback_batch_1_restores_per_page_writes():
    npages, ps = 16, 4096
    store, r = _make_region(npages=npages, ps=ps, max_writeback_batch=1)
    try:
        for pno in range(6):
            r.write(pno * ps, np.full(ps, 3, np.uint8))
        writes_before = store.num_writes
        r.flush()
        st = r.stats()
        assert store.num_writes - writes_before == 6
        assert st["coalesced_writebacks"] == 0
    finally:
        uunmap(r)


def test_dirty_storm_drains_batched_and_byte_exact():
    """Writers + watermark pressure + batched cleaners: every dirty page
    lands byte-exact, with the batched path actually engaged."""
    npages, ps = 64, 4096
    base = _pattern(npages * ps)
    store = HostArrayStore(base.copy())
    cfg = UMapConfig(page_size=ps, buffer_size=npages * ps, num_fillers=4,
                     num_evictors=2, shards=8,
                     evict_high_water=0.3, evict_low_water=0.1)
    r = umap(store, config=cfg)
    try:
        def writer(tid):
            lo = tid * 16
            for rep in range(3):
                for i in range(16):
                    r.write((lo + i) * ps, np.full(ps, 100 + tid, np.uint8))

        ts = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
        [t.start() for t in ts]
        [t.join(timeout=60) for t in ts]
        assert not any(t.is_alive() for t in ts)
        r.flush()
        st = r.stats()
        assert st["coalesced_writebacks"] >= 1, st
        for tid in range(4):
            chk = np.empty(16 * ps, np.uint8)
            store.read_into(tid * 16 * ps, chk)
            assert (chk == 100 + tid).all(), f"writer {tid} data torn"
    finally:
        uunmap(r)


# ------------------------------------- write_from_batch across all five stores


def _check_batch_write(store, total_bytes):
    """write_from_batch must byte-match a reference write_from, in ONE op."""
    rng = np.random.default_rng(7)
    payload = rng.integers(0, 256, size=total_bytes, dtype=np.uint8).view(np.uint8)
    cuts = [0, total_bytes // 5, total_bytes // 2, total_bytes]
    bufs = [payload[lo:hi] for lo, hi in zip(cuts, cuts[1:])]
    ops_before = getattr(store, "num_writes", 0)
    done = store.write_from_batch(0, bufs)
    assert done == total_bytes
    assert store.num_writes == ops_before + 1, \
        f"{type(store).__name__} batched write issued {store.num_writes - ops_before} ops"
    back = np.empty(total_bytes, np.uint8)
    store.read_into(0, back)
    assert np.array_equal(back, payload), type(store).__name__


def test_write_from_batch_hostarray():
    _check_batch_write(HostArrayStore(np.zeros(1 << 16, np.uint8)), 1 << 15)


def test_write_from_batch_file(tmp_path):
    st = FileStore(str(tmp_path / "f.bin"), size=1 << 16, create=True)
    try:
        _check_batch_write(st, 1 << 15)
    finally:
        st.close()


def test_write_from_batch_multifile(tmp_path):
    members = [FileStore(str(tmp_path / f"m{i}.bin"), size=1 << 14, create=True)
               for i in range(3)]
    st = MultiFileStore([(m, 0, 1 << 14) for m in members])
    try:
        _check_batch_write(st, 3 * (1 << 14))     # spans all three extents
    finally:
        st.close()


def test_write_from_batch_remote():
    st = RemoteStore(HostArrayStore(np.zeros(1 << 16, np.uint8)),
                     latency_s=1e-4, bandwidth_Bps=1e9)
    _check_batch_write(st, 1 << 15)
    assert st.inner.num_writes == 1               # one inner op too


def test_write_from_batch_synthetic():
    st = SyntheticStore(1 << 16, lambda off, buf: buf.fill(0))
    _check_batch_write(st, 1 << 15)


def test_write_from_batch_default_loop_matches():
    """The base-class default (loop of write_from) stays byte-compatible."""
    st = HostArrayStore(np.zeros(1 << 12, np.uint8))
    payload = _pattern(1 << 12)
    from repro.core import BackingStore
    BackingStore.write_from_batch(st, 0, [payload[:100], payload[100:]])
    back = np.empty(1 << 12, np.uint8)
    st.read_into(0, back)
    assert np.array_equal(back, payload)


def test_three_concurrent_lease_runs_dont_deadlock():
    """Regression (review finding): with the buffer small enough that three
    concurrent runs cannot all hold their pins, incomplete runs must abort
    and retry (releasing pins) rather than deadlock."""
    npages, ps, slots = 16, 4096, 8
    store, r = _make_region(npages=npages, ps=ps, slots=slots)
    cap = r.service.buffer.num_slots // 2          # == 4: 3*4 > 8 slots
    errors = []
    barrier = threading.Barrier(3)

    def worker(tid):
        try:
            barrier.wait()
            for rep in range(10):
                first = (tid * 5 + rep) % (npages - cap)
                with r.lease_run(first, cap) as run:
                    for i, v in enumerate(run.views):
                        if v[0] != _pattern(1, (first + i) * ps)[0]:
                            errors.append((tid, first + i))
        except Exception as exc:  # noqa: BLE001
            errors.append((tid, repr(exc)))

    try:
        ts = [threading.Thread(target=worker, args=(t,)) for t in range(3)]
        [t.start() for t in ts]
        [t.join(timeout=60) for t in ts]
        assert not any(t.is_alive() for t in ts), \
            "concurrent lease_runs deadlocked"
        assert not errors, errors[:5]
    finally:
        uunmap(r)


def test_abandoned_write_lease_does_not_mark_dirty():
    """Regression (review finding): lease_run's abort path releases
    write-leases whose views were never handed out — they must not dirty
    untouched pages (spurious write-back traffic)."""
    ps = 4096
    store, r = _make_region(ps=ps)
    try:
        before = r.service.table.dirty_count
        ls = r.lease(4, write=True)
        ls.abandon()
        assert r.service.table.dirty_count == before
        e = r.service.table.get((r.region_id, 4))
        assert e.pins == 0 and e.leases == 0
        ls.release()                                # no-op after abandon
        assert r.service.table.dirty_count == before
    finally:
        uunmap(r)


def test_async_checkpointer_store_mode_rejects_oversized_tree(tmp_path):
    """Regression (review finding): an image larger than one double-buffer
    slot must fail fast instead of corrupting the other slot."""
    pytest.importorskip("jax")
    from repro.ckpt.checkpoint import AsyncCheckpointer

    st = HostArrayStore(np.zeros(1 << 12, np.uint8))
    ck = AsyncCheckpointer(tmp_path, store=st)
    try:
        with pytest.raises(ValueError, match="exceeds"):
            ck.save_async(1, {"w": np.zeros(4096, np.float32)})  # 16K > 2K
    finally:
        ck.close()


def test_file_store_batch_io_chunks_past_iov_max(tmp_path):
    """Regression (review finding): pwritev/preadv reject >IOV_MAX iovecs;
    batched store I/O with thousands of buffers must chunk, not EINVAL."""
    nbufs, chunk = 1500, 64                       # > IOV_MAX = 1024
    st = FileStore(str(tmp_path / "big.bin"), size=nbufs * chunk, create=True)
    try:
        payload = _pattern(nbufs * chunk)
        bufs = [payload[i * chunk:(i + 1) * chunk] for i in range(nbufs)]
        assert st.write_from_batch(0, bufs) == nbufs * chunk
        outs = [np.empty(chunk, np.uint8) for _ in range(nbufs)]
        assert st.read_into_batch(0, outs) == nbufs * chunk
        assert np.array_equal(np.concatenate(outs), payload)
    finally:
        st.close()


def test_async_checkpointer_store_mode_double_buffers(tmp_path):
    """Regression (review finding): store-mode saves alternate halves of
    the store and publish the manifest only after the write, so the
    previously published image is never overwritten in place."""
    pytest.importorskip("jax")
    from repro.ckpt.checkpoint import (
        AsyncCheckpointer, restore_tree_from_store)

    st = HostArrayStore(np.zeros(1 << 16, np.uint8))
    ck = AsyncCheckpointer(tmp_path, store=st)
    try:
        tree1 = {"w": np.full(1000, 1.0, np.float32)}
        ck.save_async(1, tree1)
        ck.flush()
        m1 = ck.store_manifest
        ck.save_async(2, {"w": np.full(1000, 2.0, np.float32)})
        ck.flush()
        m2 = ck.store_manifest
        assert m2["step"] == 2 and m2["offset"] != m1["offset"]
        # the step-1 image survives step 2's save intact
        back1 = restore_tree_from_store(st, m1, tree1)
        assert (back1["w"] == 1.0).all()
        back2 = restore_tree_from_store(st, m2, tree1)
        assert (back2["w"] == 2.0).all()
    finally:
        ck.close()


# -------------------------------------------------- lease life-cycle property


def test_lease_lifecycle_property():
    """Property test: any interleaving of leases (read/write, page/run),
    reads, and flushes preserves byte-exactness, pin/lease balance, and
    dirty-exactly-once accounting."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    NPAGES, PS = 16, 512

    ops = st_.lists(
        st_.tuples(
            st_.sampled_from(["lease_r", "lease_w", "run", "read", "flush"]),
            st_.integers(min_value=0, max_value=NPAGES - 1),
            st_.integers(min_value=1, max_value=4),
        ),
        min_size=1, max_size=30,
    )

    @given(ops)
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(script):
        mirror = _pattern(NPAGES * PS).copy()
        store = HostArrayStore(mirror.copy())
        cfg = UMapConfig(page_size=PS, buffer_size=NPAGES * PS,
                         num_fillers=2, num_evictors=1, shards=2)
        r = umap(store, config=cfg)
        try:
            stamp = 0
            for op, pno, n in script:
                if op == "lease_r":
                    with r.lease(pno) as ls:
                        assert np.array_equal(
                            ls.view, mirror[pno * PS:(pno + 1) * PS])
                elif op == "lease_w":
                    before = r.service.table.dirty_count
                    with r.lease(pno, write=True) as ls:
                        was_dirty = r.service.table.get(
                            (r.region_id, pno)).dirty
                        stamp = (stamp + 1) % 251
                        ls.view[:] = stamp
                        mirror[pno * PS:(pno + 1) * PS] = stamp
                    after = r.service.table.dirty_count
                    assert after - before == (0 if was_dirty else 1)
                elif op == "run":
                    n = min(n, NPAGES - pno)
                    with r.lease_run(pno, n) as run:
                        for i, v in enumerate(run.views):
                            assert np.array_equal(
                                v, mirror[(pno + i) * PS:(pno + i + 1) * PS])
                elif op == "read":
                    assert np.array_equal(
                        r.read(pno * PS, PS), mirror[pno * PS:(pno + 1) * PS])
                elif op == "flush":
                    r.flush()
                    chk = np.empty(NPAGES * PS, np.uint8)
                    store.read_into(0, chk)
                    assert np.array_equal(chk, mirror)
            # balance: no pin/lease leaked by any interleaving
            for key in r.service.table.resident_keys():
                e = r.service.table.get(key)
                assert e.pins == 0 and e.leases == 0
        finally:
            uunmap(r)

    check()


# ---------------------------------------------- consumer zero-staging witnesses


def test_weight_pager_region_source_zero_staging():
    jax = pytest.importorskip("jax")
    from repro.serve.weight_pager import (
        LayerWeightPager, RegionLayerSource, pack_layer_arrays)

    rng = np.random.default_rng(0)
    layers = [rng.normal(size=(16, 16)).astype(np.float32) for _ in range(5)]
    ps = 512
    buf, specs = pack_layer_arrays(layers, ps)
    store = HostArrayStore(buf)
    cfg = UMapConfig(page_size=ps, buffer_size=64 * ps, num_fillers=2,
                     num_evictors=1)
    region = umap(store, config=cfg)
    try:
        src = RegionLayerSource(region, specs)
        for i, ref in enumerate(layers):
            assert np.allclose(np.asarray(src[i]), ref), i
        # the witness: every page arrived through a lease, none through a
        # staging copy
        st = region.stats()
        assert st["leases"] == sum(s["npages"] for s in specs)
        assert src.staging_copies == 0
        # and the full pager stack runs over the source
        import jax.numpy as jnp
        pager = LayerWeightPager(src, num_slots=3, readahead=1)
        out = pager.run(jnp.ones((4, 16), jnp.float32),
                        lambda p, x, i: jnp.tanh(x @ p))
        out.block_until_ready()
        pager.close()
    finally:
        uunmap(region)


def test_paged_kv_lease_gathers_without_staging_and_pins_sequence():
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kvcache.paged_kv import PagedKVCache, PagedKVConfig

    cfg = PagedKVConfig(num_layers=2, num_kv_heads=2, head_dim=8,
                        page_size=4, num_pages=32)
    pc = PagedKVCache(cfg)
    k = jnp.arange(2 * 10 * 2 * 8, dtype=jnp.float32).reshape(2, 10, 2, 8)
    pc.add_sequence(0, k, k + 1)
    with pc.lease_kv(0, layer=1) as ls:
        pages = pc.allocator.pages_of(0)
        want = jnp.take(pc.k_pool[1], jnp.asarray(pages), axis=0)
        assert jnp.allclose(ls.k, want)
        with pytest.raises(RuntimeError, match="lease"):
            pc.release(0)                          # pinned against free
        assert pc.evict_window_prefix(0, 4) == []  # and against eviction
    st = pc.stats()
    assert st["leases"] == 1
    assert st["lease_blocked_evictions"] == 1
    assert st["leased_sequences"] == 0             # released
    assert pc.release(0) > 0                       # free works after release
