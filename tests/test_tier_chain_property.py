"""Property suite for the N-tier chain (DESIGN.md §14).

Three invariants under randomized inputs:

* the utility score is monotone in the sampled latency delta (and in
  heat, anti-monotone in write intensity) — the calibration-driven
  ranking can never invert when a tier gets slower;
* the shadow-copy invariant: every level whose residency bit claims an
  extent holds byte-identical data to the chain's logical contents, at
  every point of a random promote/demote/write/read interleaving;
* no level ever exceeds its byte budget, and reads stay byte-exact.

Requires ``hypothesis`` (skipped when the container lacks it, same
convention as the other property suites).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pager import PagingService  # noqa: E402
from repro.core.store import HostArrayStore, TierChain  # noqa: E402

PS = 1024
EXT = 2 * PS
NEXT = 8                        # base-tier extents
FAST_SLOTS, MID_SLOTS = 2, 3

lat = st.floats(min_value=0.0, max_value=1.0,
                allow_nan=False, allow_infinity=False)
pos = st.floats(min_value=0.0, max_value=100.0,
                allow_nan=False, allow_infinity=False)


class TestUtilityFormula:
    @given(heat=pos, wheat=pos, lat_to=lat, wlat=lat,
           d1=lat, d2=lat)
    def test_monotone_in_latency_delta(self, heat, wheat, lat_to, wlat,
                                       d1, d2):
        lo, hi = sorted((d1, d2))
        u = PagingService.tier_utility
        assert (u(heat, wheat, lat_to + hi, lat_to, wlat)
                >= u(heat, wheat, lat_to + lo, lat_to, wlat))

    @given(h1=pos, h2=pos, wheat=pos, lat_from=lat, lat_to=lat, wlat=lat)
    def test_monotone_in_heat(self, h1, h2, wheat, lat_from, lat_to, wlat):
        lo, hi = sorted((h1, h2))
        u = PagingService.tier_utility
        assert (u(hi, wheat, lat_from, lat_to, wlat)
                >= u(lo, wheat, lat_from, lat_to, wlat))

    @given(heat=pos, w1=pos, w2=pos, lat_from=lat, lat_to=lat, wlat=lat)
    def test_anti_monotone_in_write_intensity(self, heat, w1, w2,
                                              lat_from, lat_to, wlat):
        lo, hi = sorted((w1, w2))
        u = PagingService.tier_utility
        assert (u(heat, hi, lat_from, lat_to, wlat)
                <= u(heat, lo, lat_from, lat_to, wlat))

    @given(heat=pos, wheat=pos, lat_from=lat, lat_to=lat, wlat=lat)
    def test_slower_placement_never_scores_access_benefit(
            self, heat, wheat, lat_from, lat_to, wlat):
        # to a tier no faster than the source, utility <= 0 net of writes
        u = PagingService.tier_utility
        if lat_to >= lat_from:
            assert u(heat, wheat, lat_from, lat_to, wlat) <= 0.0


def _fresh_chain():
    data = (np.arange(NEXT * EXT) % 251).astype(np.uint8)
    tc = TierChain(
        [HostArrayStore(np.zeros(FAST_SLOTS * EXT, np.uint8)),
         HostArrayStore(np.zeros(MID_SLOTS * EXT, np.uint8)),
         HostArrayStore(data.copy())],
        extent_size=EXT,
        budgets=[FAST_SLOTS * EXT, MID_SLOTS * EXT],
        promote_on_read=False)
    return tc, data.copy()


def _check_invariants(tc, model):
    stats = tc.tier_stats()
    # budgets: slot occupancy can never exceed the level's slot count
    assert stats["resident_by_level"][0] <= FAST_SLOTS
    assert stats["resident_by_level"][1] <= MID_SLOTS
    # shadow-copy invariant: every claimed residency is byte-identical
    # to the model (the VALID-copies-only invariant made executable)
    with tc._lock:
        claims = [(ext, lvl, tc._slots[lvl][ext])
                  for ext in range(NEXT)
                  for lvl in range(tc.base_level)
                  if tc._valid.get(ext, tc._base_bit) & (1 << lvl)]
    for ext, lvl, slot in claims:
        got = np.empty(EXT, np.uint8)
        tc.levels[lvl].read_into(slot * EXT, got)
        assert np.array_equal(got, model[ext * EXT:(ext + 1) * EXT]), \
            f"level {lvl} claims a stale copy of extent {ext}"


ops = st.lists(
    st.tuples(st.sampled_from(["promote", "demote", "write", "read"]),
              st.integers(min_value=0, max_value=NEXT - 1),
              st.integers(min_value=0, max_value=1),
              st.integers(min_value=0, max_value=255)),
    min_size=1, max_size=40)


class TestChainInvariants:
    @settings(max_examples=40, deadline=None)
    @given(ops=ops)
    def test_random_interleavings_hold_invariants(self, ops):
        tc, model = _fresh_chain()
        for kind, ext, lvl, val in ops:
            if kind == "promote":
                tc.promote(ext, level=lvl)
            elif kind == "demote":
                tc.demote(ext, level=lvl if lvl < tc.base_level else None)
            elif kind == "write":
                buf = np.full(EXT, val, np.uint8)
                tc.write_from(ext * EXT, buf)
                model[ext * EXT:(ext + 1) * EXT] = buf
            else:
                got = np.empty(EXT, np.uint8)
                tc.read_into(ext * EXT, got)
                assert np.array_equal(
                    got, model[ext * EXT:(ext + 1) * EXT]), \
                    f"read of extent {ext} returned wrong bytes"
            _check_invariants(tc, model)
        # and the chain still flushes down to a consistent base image
        tc.flush()
        base = np.empty(NEXT * EXT, np.uint8)
        tc.levels[-1].read_into(0, base)
        assert np.array_equal(base, model)

    @settings(max_examples=20, deadline=None)
    @given(ops=ops, seed=st.integers(min_value=0, max_value=2**31))
    def test_demand_faults_between_migrations(self, ops, seed):
        """Same invariants with promote-on-read faulting interleaved."""
        tc, model = _fresh_chain()
        tc.promote_on_read = True
        rng = np.random.default_rng(seed)
        for kind, ext, lvl, val in ops:
            if kind == "promote":
                tc.promote(ext, level=lvl)
            elif kind == "demote":
                tc.demote(ext)
            elif kind == "write":
                buf = np.full(PS, val, np.uint8)
                off = ext * EXT + (PS if val % 2 else 0)
                tc.write_from(off, buf)
                model[off:off + PS] = buf
            else:
                pno = int(rng.integers(0, NEXT * 2))
                got = np.empty(PS, np.uint8)
                tc.read_into(pno * PS, got)
                assert np.array_equal(got, model[pno * PS:(pno + 1) * PS])
            _check_invariants(tc, model)
