"""End-to-end I/O error propagation (DESIGN.md §14.4).

The seed swallowed backing-store exceptions in the filler and cleaner
pools (``traceback.print_exc`` + abandon), which turned a failing store
into an infinite re-fault loop on the read side and silently stranded
dirty pages on the write side.  These tests pin the repaired contract:

  * a fill that dies on a store exception raises ``IOError`` at every
    blocked fault site within one wait timeout — no hang, no re-fault
    loop — and counts in the ``io_errors`` shard counter;
  * a failed write-back retries (bounded by ``writeback_retries``), then
    quarantines the page (resident + dirty, never dropped) and
    ``flush_region`` raises; transient failures recover through the
    retry path;
  * fault injection is exercised across all five concrete stores, single
    and batched ops, via the reusable ``FaultyStore`` wrapper;
  * the multi-shard ``_abandon_fills`` regression: abandoning a batch
    spanning several stripes wakes every stripe's waiters.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    FaultyStore,
    FileStore,
    HostArrayStore,
    MultiFileStore,
    RemoteStore,
    SyntheticStore,
    UMapConfig,
    umap,
    uunmap,
)

PAGE = 4096
NPAGES = 64


def _pattern_gen(offset: int, buf: np.ndarray) -> None:
    n = buf.nbytes
    buf[:] = (np.arange(offset, offset + n, dtype=np.int64) % 251).astype(np.uint8)


def _expected(offset: int, nbytes: int) -> np.ndarray:
    return (np.arange(offset, offset + nbytes, dtype=np.int64) % 251).astype(np.uint8)


def _make_store(kind: str, tmp_path):
    """One of the five concrete stores, pre-filled with the pattern."""
    data = _expected(0, NPAGES * PAGE)
    if kind == "host":
        return HostArrayStore(data.copy())
    if kind == "file":
        p = tmp_path / "store.bin"
        data.tofile(p)
        return FileStore(str(p))
    if kind == "multifile":
        half = NPAGES * PAGE // 2
        pa, pb = tmp_path / "a.bin", tmp_path / "b.bin"
        data[:half].tofile(pa)
        data[half:].tofile(pb)
        return MultiFileStore([(FileStore(str(pa)), 0, half),
                               (FileStore(str(pb)), 0, half)])
    if kind == "remote":
        return RemoteStore(HostArrayStore(data.copy()), latency_s=1e-4)
    if kind == "synthetic":
        return SyntheticStore(NPAGES * PAGE, _pattern_gen)
    raise ValueError(kind)


ALL_STORES = ("host", "file", "multifile", "remote", "synthetic")


def _region(store, **cfg_kw):
    cfg = UMapConfig(page_size=PAGE, buffer_size=16 * PAGE, num_fillers=2,
                     num_evictors=1, **cfg_kw)
    return umap(store, config=cfg)


# ------------------------------------------------------ FaultyStore wrapper


def test_faulty_store_gating_and_counters():
    st = FaultyStore(HostArrayStore(np.zeros(8 * PAGE, np.uint8)),
                     fail_after_reads=2, fail_after_writes=1, fail_count=1)
    buf = np.empty(PAGE, np.uint8)
    st.read_into(0, buf)
    st.read_into_batch(0, [buf])          # a batch op counts as ONE operation
    with pytest.raises(OSError):
        st.read_into(0, buf)
    st.read_into(0, buf)                  # fail_count=1: recovered
    st.write_from(0, buf)
    with pytest.raises(OSError):
        st.write_from_batch(0, [buf])
    assert st.reads_attempted == 4 and st.reads_failed == 1
    assert st.writes_attempted == 2 and st.writes_failed == 1


# ------------------------------------------------- fill (read) failures


@pytest.mark.parametrize("kind", ALL_STORES)
@pytest.mark.parametrize("batch", [1, 8], ids=["single", "batched"])
def test_fill_failure_raises_ioerror_no_hang(kind, batch, tmp_path):
    store = FaultyStore(_make_store(kind, tmp_path), fail_after_reads=0)
    region = _region(store, max_batch_pages=batch)
    t0 = time.perf_counter()
    with pytest.raises(IOError):
        region.read(0, 4 * PAGE)          # multi-page: exercises batch path
    assert time.perf_counter() - t0 < 5.0, "fault site must not hang"
    snap = region.stats()
    assert snap["io_errors"] >= 1
    # The store recovers: a FRESH fault retries and succeeds (failed fills
    # leave the table; the application's retry is a new fault).
    store.fail_after_reads = None
    out = region.read(0, 4 * PAGE)
    assert np.array_equal(out, _expected(0, 4 * PAGE))
    uunmap(region)


def test_fill_failure_propagates_to_every_waiter():
    store = FaultyStore(
        RemoteStore(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)),
                    latency_s=0.02),
        fail_after_reads=0)
    region = _region(store)
    results = []

    def reader():
        try:
            region.read(0, PAGE)          # same page: all block on one fill
            results.append("ok")
        except IOError:
            results.append("ioerror")

    ts = [threading.Thread(target=reader) for _ in range(4)]
    [t.start() for t in ts]
    [t.join(timeout=10.0) for t in ts]
    assert not any(t.is_alive() for t in ts), "a waiter slept through the error"
    assert results == ["ioerror"] * 4
    uunmap(region)


def test_fill_callback_failure_raises_ioerror():
    def bad_fill(page_no, buf):
        raise RuntimeError("app resolver died")

    region = _region(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)),
                     fill_callback=bad_fill)
    with pytest.raises(IOError):
        region.read(0, PAGE)
    uunmap(region)


def test_fill_failure_does_not_leak_buffer_slots():
    store = FaultyStore(HostArrayStore(_expected(0, NPAGES * PAGE)),
                        fail_after_reads=0, fail_count=20)
    region = _region(store)
    for lo in range(0, 20 * PAGE, PAGE):
        with pytest.raises(IOError):
            region.read(lo, PAGE)
    store.fail_after_reads = None
    # 16-slot buffer: if failed fills leaked their slots, filling the whole
    # region would stall on allocation instead of evicting through.
    out = region.read(0, NPAGES * PAGE)
    assert np.array_equal(out, _expected(0, NPAGES * PAGE))
    assert region.service.buffer.used_slots <= 16
    uunmap(region)


# ------------------------------------------------ write-back failures


@pytest.mark.parametrize("kind", ALL_STORES)
@pytest.mark.parametrize("npages_dirty", [1, 4], ids=["single", "batched"])
def test_writeback_transient_failure_recovers(kind, npages_dirty, tmp_path):
    # 4 adjacent dirty pages coalesce into ONE write_from_batch run, so the
    # batched variant injects the failure into the vectorized write path.
    store = FaultyStore(_make_store(kind, tmp_path), fail_after_writes=0,
                        fail_count=1)
    region = _region(store)
    payload = np.full(npages_dirty * PAGE, 7, np.uint8)
    region.write(3 * PAGE, payload)
    region.flush()                         # retry path absorbs the one failure
    snap = region.stats()
    assert snap["writeback_errors"] >= 1
    assert snap["quarantined_pages"] == 0
    check = np.empty(npages_dirty * PAGE, np.uint8)
    store.read_into(3 * PAGE, check)
    assert (check == 7).all(), "retried write-back must persist the bytes"
    uunmap(region)


def test_writeback_retry_budget_resets_per_episode():
    """Review regression: wb_retries must reset on a successful write-back
    — N transient failures spread over a page's lifetime must not
    quarantine it (the bound is per episode, not cumulative)."""
    store = FaultyStore(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)))
    cfg = UMapConfig(page_size=PAGE, buffer_size=16 * PAGE, num_fillers=2,
                     num_evictors=1, writeback_retries=2)
    region = umap(store, config=cfg)
    for episode in range(3):
        # Fail exactly the NEXT write, then recover: one transient failure
        # per episode, each within the 2-attempt budget.
        store.fail_after_writes = store.writes_attempted
        store.fail_count = store.writes_failed + 1
        region.write(0, np.full(PAGE, 50 + episode, np.uint8))
        region.flush()
    snap = region.stats()
    assert snap["writeback_errors"] == 3
    assert snap["quarantined_pages"] == 0, \
        "transient failures across episodes must not accumulate to quarantine"
    check = np.empty(PAGE, np.uint8)
    store.read_into(0, check)
    assert (check == 52).all()
    uunmap(region)


def test_writeback_exhaustion_quarantines_and_flush_raises():
    store = FaultyStore(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)),
                        fail_after_writes=0)
    cfg = UMapConfig(page_size=PAGE, buffer_size=16 * PAGE, num_fillers=2,
                     num_evictors=1, writeback_retries=2)
    region = umap(store, config=cfg)
    region.write(0, np.full(PAGE, 9, np.uint8))
    with pytest.raises(IOError):
        region.flush()
    snap = region.stats()
    assert snap["writeback_errors"] >= 2      # bounded retries, all counted
    assert snap["quarantined_pages"] == 1
    # The quarantined page's bytes are still served from the buffer — the
    # dirty data is stranded, not lost.
    assert (region.read(0, PAGE) == 9).all()
    # Recovery after the store comes back: un-quarantine is not automatic
    # (by design), but the service still shuts down cleanly.
    store.fail_after_writes = None
    with pytest.raises(IOError):
        uunmap(region)                        # close flushes -> still reports
    # Review regression: the raise must not leak the region or the owned
    # service — unregistration and thread shutdown happen either way.
    assert region.region_id not in region.service._regions
    assert region.service._closed


def test_quarantined_page_never_evicted_under_pressure():
    store = FaultyStore(HostArrayStore(_expected(0, NPAGES * PAGE)),
                        fail_after_writes=0)
    cfg = UMapConfig(page_size=PAGE, buffer_size=8 * PAGE, num_fillers=2,
                     num_evictors=1, writeback_retries=1)
    region = umap(store, config=cfg)
    region.write(0, np.full(PAGE, 5, np.uint8))
    with pytest.raises(IOError):
        region.flush()                        # quarantine page 0
    # Capacity churn over the whole region: the quarantined page must
    # survive (evicting it would drop the only copy of its dirty bytes).
    for p in range(1, NPAGES):
        region.read(p * PAGE, PAGE)
    assert (region.read(0, PAGE) == 5).all()
    snap = region.stats()
    assert snap["quarantined_pages"] == 1


# ---------------------------------------------- multi-shard abandon (§14.4)


def test_abandon_fills_wakes_waiters_on_every_shard():
    """Closing a region with queued fills + waiters spanning all stripes:
    every waiter must observe the closing gate promptly (the audit's
    regression: no stripe's waiters may sleep through the abandon)."""
    store = RemoteStore(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)),
                        latency_s=0.05)
    cfg = UMapConfig(page_size=PAGE, buffer_size=32 * PAGE, num_fillers=2,
                     num_evictors=1, shards=8, max_batch_pages=1)
    region = umap(store, config=cfg)
    assert len(region.service.shards) == 8
    outcomes = []
    started = threading.Barrier(9)

    def reader(p):
        started.wait()
        try:
            region.read(p * PAGE, PAGE)
            outcomes.append("ok")
        except (RuntimeError, IOError):
            outcomes.append("closed")

    # One waiter per shard-ish: 8 distinct pages hash across the stripes.
    ts = [threading.Thread(target=reader, args=(p,)) for p in range(8)]
    [t.start() for t in ts]
    started.wait()
    time.sleep(0.01)                  # let the faults post + block
    region.close()
    [t.join(timeout=10.0) for t in ts]
    assert not any(t.is_alive() for t in ts), \
        "a waiter slept through a multi-shard abandon"
    assert len(outcomes) == 8
    region.service.close()


# ------------------------------------------------------- stats parity


def test_error_counters_in_snapshot_and_per_shard():
    store = FaultyStore(HostArrayStore(np.zeros(NPAGES * PAGE, np.uint8)),
                        fail_after_reads=0, fail_count=1)
    region = _region(store)
    with pytest.raises(IOError):
        region.read(0, PAGE)
    snap = region.stats()
    for key in ("io_errors", "writeback_errors", "quarantined_pages"):
        assert key in snap
        assert all(key in s for s in snap["per_shard"])
    assert snap["io_errors"] == sum(s["io_errors"] for s in snap["per_shard"])
    uunmap(region)
