"""Adaptive engine + batched fault coalescing (DESIGN.md §8–9).

Covers: classifier phase detection (sequential -> random transition, stride
detection, hysteresis damping), batched-fill correctness (coalesced runs
install every page, blocked readers wake, stats count, fewer store calls),
static-hint precedence, runtime policy swap, and an mmap_compat regression
proving ``adaptive=False`` preserves the seed behavior.
"""

import random
import threading

import numpy as np
import pytest

from repro.core import (
    AccessAdvice,
    AccessPatternClassifier,
    HostArrayStore,
    Phase,
    RemoteStore,
    UMapConfig,
    advice_for_phase,
    phase_for_advice,
    umap,
    uunmap,
)


# --------------------------------------------------------------- classifier


def make_clf(**kw):
    kw.setdefault("window", 16)
    kw.setdefault("min_samples", 8)
    kw.setdefault("interval", 4)
    kw.setdefault("hysteresis", 2)
    return AccessPatternClassifier(**kw)


def feed(clf, pages):
    last = None
    for p in pages:
        d = clf.observe(p)
        if d is not None:
            last = d
    return last


def test_sequential_detection():
    clf = make_clf()
    d = feed(clf, range(64))
    assert d is not None and d.phase is Phase.SEQUENTIAL
    assert d.stride == 1 and d.read_ahead > 0


def test_sequential_to_random_transition():
    clf = make_clf()
    feed(clf, range(64))
    assert clf.phase is Phase.SEQUENTIAL
    rng = random.Random(7)
    d = feed(clf, [rng.randrange(100_000) for _ in range(200)])
    assert clf.phase is Phase.RANDOM
    assert d is not None and d.read_ahead == 0
    assert clf.transitions >= 1


def test_stride_detection():
    clf = make_clf()
    d = feed(clf, range(0, 64 * 7, 7))
    assert d is not None and d.phase is Phase.STRIDED and d.stride == 7


def test_hysteresis_damps_noise():
    """A few stray faults inside a sequential scan must not flip the phase."""
    clf = make_clf(window=16, min_samples=8, interval=4, hysteresis=3)
    feed(clf, range(64))
    assert clf.phase is Phase.SEQUENTIAL
    # one noisy burst shorter than hysteresis*interval, then sequential again
    feed(clf, [9000, 17, 4400])
    feed(clf, range(64, 128))
    assert clf.phase is Phase.SEQUENTIAL
    assert clf.transitions == 0


def test_scan_with_reuse_detection():
    """A cyclic scan (revisit after wraparound) classifies as SCAN_REUSE."""
    clf = make_clf(window=16, min_samples=8, interval=4, hysteresis=1)
    for _ in range(4):                      # loop over the same 24 pages
        feed(clf, range(24))
    assert clf.phase is Phase.SCAN_REUSE
    from repro.core import PHASE_SETTINGS
    assert PHASE_SETTINGS[Phase.SCAN_REUSE]["eviction_policy"] == "swa"


def test_phase_advice_bridge_round_trip():
    assert advice_for_phase(Phase.SEQUENTIAL) is AccessAdvice.SEQUENTIAL
    assert advice_for_phase(Phase.SCAN_REUSE) is AccessAdvice.STREAMING
    assert phase_for_advice(AccessAdvice.STRIDED) is Phase.STRIDED
    for ph in Phase:
        assert phase_for_advice(advice_for_phase(ph)) in Phase


# ------------------------------------------------------------ batched fills


def make_region(nbytes=256 * 4096, page_size=4096, slots=None, **cfg_kw):
    data = (np.arange(nbytes) % 251).astype(np.uint8)
    store = HostArrayStore(data.copy())
    slots = slots if slots is not None else nbytes // page_size
    cfg = UMapConfig(page_size=page_size, buffer_size=slots * page_size,
                     num_fillers=4, num_evictors=2, **cfg_kw)
    return umap(store, config=cfg), data, store


def test_coalesced_fill_correct_and_counted():
    r, data, store = make_region(max_batch_pages=16)
    try:
        out = r.read(0, 128 * 4096)         # posts 128 adjacent fills up front
        assert np.array_equal(out, data[: 128 * 4096])
        st = r.stats()
        assert st["coalesced_fills"] >= 1, "no fills were coalesced"
        assert st["coalesced_pages"] > st["coalesced_fills"]
        # vectorized store: far fewer read calls than pages moved
        assert store.num_reads < 128
    finally:
        uunmap(r)


def test_coalescing_disabled_matches_page_count():
    r, data, store = make_region(max_batch_pages=1)
    try:
        out = r.read(0, 128 * 4096)
        assert np.array_equal(out, data[: 128 * 4096])
        st = r.stats()
        assert st["coalesced_fills"] == 0
        assert store.num_reads >= 128       # one store call per page
    finally:
        uunmap(r)


def test_coalesced_fill_wakes_all_blocked_readers():
    """Threads blocked on different pages of one run all wake on install."""
    nbytes = 64 * 4096
    inner = HostArrayStore((np.arange(nbytes) % 251).astype(np.uint8))
    store = RemoteStore(inner, latency_s=5e-3, bandwidth_Bps=1e9)
    cfg = UMapConfig(page_size=4096, buffer_size=64 * 4096, num_fillers=2,
                     num_evictors=1, max_batch_pages=32)
    r = umap(store, config=cfg)
    results, errors = {}, []

    def reader(pno):
        try:
            got = r.read(pno * 4096, 4096)
            results[pno] = got[0]
        except Exception as e:  # pragma: no cover - diagnostic
            errors.append((pno, e))

    try:
        r.service.request_fills(r, list(range(32)))   # one adjacent run
        ts = [threading.Thread(target=reader, args=(p,)) for p in range(32)]
        [t.start() for t in ts]
        [t.join(timeout=10) for t in ts]
        assert not errors
        assert len(results) == 32, "a blocked reader never woke"
        st = r.stats()
        assert st["coalesced_fills"] >= 1
        # the run paid ~1 latency charge, not 32 (store calls, not pages)
        assert store.num_reads < 32
    finally:
        uunmap(r)


def test_batch_respects_store_hint():
    """Effective batch = min(config.max_batch_pages, store.batch_read_hint)."""
    r, data, store = make_region(max_batch_pages=64)
    try:
        store.batch_read_hint = 4
        r.read(0, 64 * 4096)
        st = r.stats()
        if st["coalesced_fills"]:
            assert st["coalesced_pages"] / st["coalesced_fills"] <= 4
    finally:
        uunmap(r)


# --------------------------------------------------------- adaptive regions


def test_adaptive_sequential_scan_cuts_demand_faults():
    r, data, _ = make_region(adaptive=True, pattern_min_samples=8,
                             pattern_interval=4, pattern_hysteresis=2)
    try:
        for pno in range(256):
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        st = r.stats()
        assert st["pattern_transitions"] >= 1, "classifier never retuned"
        assert r.readahead_pages > 0, "readahead was not raised"
        assert st["demand_faults"] < 256, "adaptation saved no faults"
        snap = r.service.pattern_snapshot(r.region_id)
        assert snap["phase"] == "sequential"
    finally:
        uunmap(r)


def test_adaptive_backward_strided_scan_prefetches_downward():
    """Negative detected stride must read ahead *downward* (review fix)."""
    n = 512 * 4096
    data = (np.arange(n) % 251).astype(np.uint8)
    cfg = UMapConfig(page_size=4096, buffer_size=512 * 4096, num_fillers=4,
                     num_evictors=2, adaptive=True, pattern_min_samples=8,
                     pattern_interval=4, pattern_hysteresis=2)
    r = umap(HostArrayStore(data.copy()), config=cfg)
    try:
        for pno in range(511, 200, -2):
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        snap = r.service.pattern_snapshot(r.region_id)
        assert snap["phase"] == "strided" and snap["stride"] == -2
        assert r.stats()["prefetch_hits"] > 0, "no downward readahead hits"
    finally:
        uunmap(r)


def test_static_hint_pins_region_against_classifier():
    """Explicit readahead_pages => classifier must never retune (§3.6 bridge)."""
    data = (np.arange(256 * 4096) % 251).astype(np.uint8)
    store = HostArrayStore(data.copy())
    cfg = UMapConfig(page_size=4096, buffer_size=256 * 4096, num_fillers=4,
                     num_evictors=2, adaptive=True, pattern_min_samples=8,
                     pattern_interval=4, pattern_hysteresis=2)
    r = umap(store, config=cfg, readahead_pages=3)
    try:
        assert r.hint_pinned
        for pno in range(256):
            r.read(pno * 4096, 4096)
        assert r.readahead_pages == 3, "classifier overrode a pinned hint"
        assert r.stats()["pattern_transitions"] == 0
    finally:
        uunmap(r)


def test_advise_pins_and_applies_settings():
    r, data, _ = make_region(adaptive=True)
    try:
        r.advise(AccessAdvice.STREAMING)
        assert r.hint_pinned
        assert r.readahead_pages == 16
        assert r.service.policy.name == "swa"
        for pno in range(128):
            r.read(pno * 4096, 4096)
        assert r.readahead_pages == 16      # still pinned
    finally:
        uunmap(r)


def test_runtime_policy_swap_preserves_residency():
    r, data, _ = make_region(nbytes=64 * 4096, slots=16)
    try:
        for pno in range(32):
            r.read(pno * 4096, 4096)
        resident_before = r.service.resident_pages()
        r.service.set_eviction_policy("swa")
        assert r.service.policy.name == "swa"
        assert r.service.resident_pages() == resident_before
        # eviction still functions under the swapped policy
        for pno in range(32, 64):
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        assert r.service.buffer.used_slots <= 16
    finally:
        uunmap(r)


# ----------------------------------------------------- regression (baseline)


def test_mmap_compat_unaffected_by_new_engine():
    """adaptive=False + mmap_compat: byte-identical semantics to the seed —
    synchronous resolution, heuristic readahead, no coalescing, no retunes."""
    nbytes = 128 * 4096
    data = (np.arange(nbytes) % 251).astype(np.uint8)
    cfg = UMapConfig.mmap_baseline(buffer_size=64 * 4096)
    assert cfg.adaptive is False and cfg.max_batch_pages == 1
    r = umap(HostArrayStore(data.copy()), config=cfg)
    try:
        assert len(r.service._fillers) == 0
        for pno in range(64):
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        st = r.stats()
        assert st["coalesced_fills"] == 0
        assert st["pattern_transitions"] == 0
        assert st["prefetch_fills"] > 0          # heuristic readahead intact
        assert st["demand_faults"] < 64
    finally:
        uunmap(r)


def test_default_config_has_adaptive_off():
    cfg = UMapConfig()
    assert cfg.adaptive is False
    r, data, _ = make_region()               # defaults: no classifier attached
    try:
        r.read(0, 4096)
        assert r.service.pattern_snapshot(r.region_id) is None
        assert r.stats()["pattern_transitions"] == 0
    finally:
        uunmap(r)
