"""Runtime substrate tests: kvcache, serving engine, data pipeline,
checkpointing (+async/restart/elastic), collectives, weight pager, trainer."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import get_smoke_config
from repro.core import HostArrayStore, UMapConfig
from repro.data.pipeline import lm_batches
from repro.distributed.collectives import (
    compress_grads,
    decompress_grads,
    init_error_state,
)
from repro.kvcache.allocator import OutOfPages, PageAllocator
from repro.kvcache.paged_kv import ContiguousKVCache, PagedKVCache, PagedKVConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.weight_pager import LayerWeightPager
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.train_step import TrainConfig
from repro.train.optimizer import AdamWConfig


# ------------------------------------------------------------------ kvcache


def test_page_allocator_accounting():
    a = PageAllocator(10)
    p1 = a.alloc(1, 3)
    p2 = a.alloc(2, 4)
    assert a.used_pages == 7 and len(set(p1) & set(p2)) == 0
    assert a.pages_of(1) == p1
    a.free_seq(1)
    assert a.used_pages == 4
    with pytest.raises(OutOfPages):
        a.alloc(3, 7)
    dropped = a.free_prefix(2, 2)
    assert dropped == p2[:2] and a.pages_of(2) == p2[2:]
    row = a.table_for(2, 8)
    assert list(row[:2]) == p2[2:] and (row[2:] == 0).all()


def test_paged_kv_cache_roundtrip_and_attend():
    cfg = PagedKVConfig(num_layers=2, num_kv_heads=2, head_dim=8,
                        page_size=4, num_pages=16, max_pages_per_seq=4)
    cache = PagedKVCache(cfg)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(2, 10, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 10, 2, 8)), jnp.float32)
    cache.add_sequence(7, k, v)
    assert cache.seq_len[7] == 10
    cache.append_token(7, k[:, 0], v[:, 0])
    assert cache.seq_len[7] == 11
    q = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    out = cache.attend(0, q, [7], impl="ref")
    assert out.shape == (1, 4, 8) and np.isfinite(np.asarray(out)).all()
    stats = cache.stats()
    assert stats["sequences"] == 1 and stats["pages_used"] == 3
    assert cache.release(7) == 3
    assert cache.allocator.used_pages == 0


def test_paged_vs_contiguous_memory_accounting():
    """The paged cache reserves ~actual tokens; contiguous reserves max_len."""
    paged = PagedKVConfig(num_layers=1, num_kv_heads=1, head_dim=4,
                          page_size=4, num_pages=64)
    pc = PagedKVCache(paged)
    cc = ContiguousKVCache(1, 1, 4, max_seqs=8, max_len=64)
    rng = np.random.default_rng(0)
    for sid, L in enumerate([5, 9, 17]):
        k = jnp.asarray(rng.normal(size=(1, L, 1, 4)), jnp.float32)
        pc.add_sequence(sid, k, k)
        cc.add_sequence(sid, k, k)
    paged_tokens = pc.allocator.used_pages * paged.page_size
    assert paged_tokens == 8 + 12 + 20            # rounded up to pages
    assert cc.reserved_tokens() == 3 * 64          # mmap-style over-reserve
    assert cc.used_tokens() == 31


# ------------------------------------------------------------- serve engine


def test_serve_engine_generates_and_matches_unbatched():
    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(max_batch=4, page_size=4, num_pages=128,
                        max_pages_per_seq=32, prefill_bucket=16)
    eng = ServeEngine(cfg, params, ecfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in (5, 9, 7)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run_until_drained(max_steps=200)
    assert len(eng.finished) == 3
    assert eng.allocator.used_pages == 1, "pages leaked after retire (scratch only)"

    # reference: greedy decode via plain prefill+decode, one sequence at a time
    for req in eng.finished:
        toks = list(req.prompt)
        cache = M.init_cache(cfg, 1, 64)
        batch = {"tokens": jnp.asarray([toks[:-1]], jnp.int32)}
        _, cache = M.prefill(cfg, params, batch, cache)
        out = []
        cur = len(toks) - 1                 # position of the pending token
        for _ in range(4):
            logits, cache = M.decode_step(
                cfg, params, cache, jnp.asarray([toks[-1]], jnp.int32),
                jnp.asarray([cur], jnp.int32))
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            toks.append(nxt)
            cur += 1
        assert out == req.generated, (out, req.generated)


def test_serve_engine_straggler_requeue():
    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, EngineConfig(
        max_batch=2, page_size=4, num_pages=64, max_pages_per_seq=16,
        prefill_bucket=8))
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=3, deadline_s=-1.0)  # instantly late
    eng.submit(req)
    eng.step()  # admits + prefills
    eng.step()  # deadline check fires -> requeue
    assert eng.stats["requeues"] >= 1
    assert req.restarts >= 1


# ------------------------------------------------------------- data pipeline


def test_lm_batches_out_of_core():
    vocab = 100
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, vocab, size=20_000, dtype=np.int32)
    store = HostArrayStore(tokens.view(np.uint8).copy())
    cfg = UMapConfig(page_size=4096, buffer_size=8 * 4096, num_fillers=2,
                     num_evictors=1, read_ahead=4, eviction_policy="swa")
    loader, reader = lm_batches(store, batch_size=4, seq_len=32, config=cfg)
    n, seen = 0, 0
    for batch in loader:
        assert batch["tokens"].shape == (4, 32)
        # next-token alignment
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])
        start = n * 4 * 33
        ref = tokens[start : start + 4 * 33].reshape(4, 33)
        np.testing.assert_array_equal(batch["tokens"], ref[:, :-1])
        n += 1
        seen += batch["tokens"].size
    assert n == 20_000 // (4 * 33)
    st = reader.stats()
    assert st["prefetch_fills"] > 0, "streaming readahead inactive"
    reader.close()


# -------------------------------------------------------------- checkpoints


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), {"c": jnp.zeros(2)}]}
    for step in (10, 20, 30, 40):
        ckpt.save(tmp_path, step, tree)
    assert ckpt.latest_step(tmp_path) == 40
    back = ckpt.restore(tmp_path, 40, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    removed = ckpt.gc_old(tmp_path, keep=2)
    assert removed == 2 and ckpt.latest_step(tmp_path) == 40


def test_async_checkpointer_watermarks(tmp_path):
    c = ckpt.AsyncCheckpointer(tmp_path, writers=1, high_water=2, low_water=1,
                               keep=10)
    tree = {"w": jnp.ones((64, 64))}
    for step in range(1, 6):
        c.save_async(step, tree)
    c.flush()
    assert c.stats["saves"] == 5
    assert ckpt.latest_step(tmp_path) == 5
    c.close()


def test_trainer_checkpoint_restart(tmp_path):
    cfg = get_smoke_config("smollm-135m")
    tcfg = TrainerConfig(
        train=TrainConfig(optimizer=AdamWConfig(learning_rate=1e-3,
                                                warmup_steps=2, total_steps=8),
                          loss_chunk=8),
        total_steps=4, ckpt_dir=str(tmp_path), ckpt_every=2, log_every=1)
    rng = np.random.default_rng(0)

    def batches(n):
        for _ in range(n):
            t = rng.integers(0, cfg.vocab_size, size=(2, 17), dtype=np.int64)
            yield {"tokens": t[:, :-1], "labels": t[:, 1:]}

    t1 = Trainer(cfg, tcfg)
    r1 = t1.fit(batches(10))
    assert r1["final_step"] == 4 and np.isfinite(r1["loss"])
    # simulate restart: a new trainer resumes from the durable checkpoint
    t2 = Trainer(cfg, tcfg.__class__(**{**tcfg.__dict__, "total_steps": 6}))
    assert t2.try_resume()
    assert t2.step == 4
    r2 = t2.fit(batches(10))
    assert r2["final_step"] == 6


def test_elastic_restore_across_meshes(tmp_path):
    """Save from one layout, restore + re-place on a different mesh."""
    from repro.distributed.elastic import plan_remesh, reshard_tree

    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(cfg, jax.random.key(0))
    ckpt.save(tmp_path, 1, params)
    arrays = ckpt.restore(tmp_path, 1, params)
    mesh = jax.make_mesh((1,), ("model",))
    report = plan_remesh(cfg, mesh)
    assert report.devices == 1
    placed = reshard_tree(cfg, mesh, arrays)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------- collectives


def test_int8_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    err = init_error_state(g)
    acc = jnp.zeros_like(g["w"])
    # repeated compression of the same gradient: error feedback makes the
    # *accumulated* dequantized sum converge to n*g (bias-free).
    n = 50
    for _ in range(n):
        q, s, err = compress_grads(g, err)
        acc = acc + decompress_grads(q, s)["w"]
    rel = float(jnp.abs(acc / n - g["w"]).max() / jnp.abs(g["w"]).max())
    assert rel < 2e-3, f"error feedback did not debias: rel={rel}"


def test_int8_compression_is_4x_smaller():
    g = {"w": jnp.ones((128, 128), jnp.float32)}
    q, s, _ = compress_grads(g, init_error_state(g))
    assert q["w"].dtype == jnp.int8
    assert q["w"].size * 1 == g["w"].size  # int8: 4x fewer bytes than fp32


# ------------------------------------------------------------- weight pager


def test_weight_pager_streams_layers_correctly():
    rng = np.random.default_rng(0)
    layers = [{"w": np.asarray(rng.normal(size=(8, 8)), np.float32)}
              for _ in range(6)]
    pager = LayerWeightPager(layers, num_slots=3, readahead=2)
    x = jnp.ones((1, 8), jnp.float32)

    def apply_fn(p, x, i):
        return x @ jnp.asarray(p["w"])

    out = pager.run(x, apply_fn)
    ref = x
    for l in layers:
        ref = ref @ jnp.asarray(l["w"])
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    st = pager.stats
    assert st["fills"] >= 6
    assert st["evictions"] >= 2   # ring smaller than layer count
    pager.close()


# ------------------------------------------------------- shard-local MoE


def test_moe_shard_local_matches_dense():
    """shard_map-local dispatch (TP and EP) == dense dispatch on a 1x1 mesh."""
    from jax.sharding import PartitionSpec  # noqa: F401
    from repro.distributed.sharding import use_mesh
    from repro.models.moe import (
        _moe_forward_dense,
        _moe_forward_shard_local,
        moe_param_specs,
    )
    from repro.models.common import init_param_tree

    d, ff, E, K = 16, 32, 4, 2
    x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for kind in ("tp", "ep"):
        p = init_param_tree(moe_param_specs(d, ff, E, kind),
                            jax.random.key(0), jnp.float32)
        y_ref, aux_ref = _moe_forward_dense(p, x, K, 8.0)
        with use_mesh(mesh):
            y, aux = _moe_forward_shard_local(p, x, K, 8.0, kind, mesh)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(float(aux["moe_lb_loss"]),
                                   float(aux_ref["moe_lb_loss"]), rtol=1e-5)
