"""Per-kernel validation: shape/dtype sweeps, interpret=True vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.page_gather.kernel import page_gather, page_scatter
from repro.kernels.page_gather.ref import page_gather_ref, page_scatter_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

TOL = dict(float32=dict(atol=2e-5, rtol=2e-5),
           bfloat16=dict(atol=3e-2, rtol=3e-2))


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("b,h,kvh,sq,sk,d", [
    (1, 4, 4, 32, 32, 16),       # MHA square
    (2, 8, 2, 64, 64, 32),       # GQA
    (1, 4, 1, 48, 80, 64),       # MQA, ragged lengths (padding path)
    (2, 2, 2, 16, 128, 128),     # long KV, wide head
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, b, h, kvh, sq, sk, d, causal):
    if causal and sq != sk:
        pytest.skip("causal requires aligned q/k positions in this harness")
    q = rand(0, (b, h, sq, d), dtype)
    k = rand(1, (b, kvh, sk, d), dtype)
    v = rand(2, (b, kvh, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=32,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_sliding_window():
    b, h, s, d = 1, 2, 64, 16
    q, k, v = (rand(i, (b, h, s, d), "float32") for i in range(3))
    out = flash_attention(q, k, v, causal=True, window=8, block_q=16,
                          block_k=16, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_block_shape_independence():
    """Block size must never change the result."""
    b, h, s, d = 1, 2, 96, 32
    q, k, v = (rand(i, (b, h, s, d), "float32") for i in range(3))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(16, 16), (32, 48), (96, 96)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ paged attention


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("b,h,kvh,d,pages,ps,pps", [
    (2, 4, 4, 32, 16, 8, 4),
    (3, 8, 2, 64, 32, 16, 6),
    (1, 4, 1, 128, 8, 8, 2),
])
def test_paged_attention_sweep(dtype, b, h, kvh, d, pages, ps, pps):
    rng = np.random.default_rng(0)
    q = rand(0, (b, h, d), dtype)
    kp = rand(1, (pages, ps, kvh, d), dtype)
    vp = rand(2, (pages, ps, kvh, d), dtype)
    table = jnp.asarray(
        rng.choice(pages, size=(b, pps), replace=False), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, pps * ps + 1, size=b), jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_paged_attention_page_size_invariance():
    """Same logical KV split at different page sizes -> same output.

    This is the correctness half of the paper's §3.6 claim: page size is a
    *performance* knob, never a semantics knob.
    """
    b, h, kvh, d = 2, 4, 2, 32
    S = 64
    k_seq = rand(1, (b, S, kvh, d), "float32")
    v_seq = rand(2, (b, S, kvh, d), "float32")
    q = rand(0, (b, h, d), "float32")
    lengths = jnp.asarray([S, S // 2], jnp.int32)
    outs = []
    for ps in (8, 16, 32):
        n = S // ps
        kp = k_seq.reshape(b * n, ps, kvh, d)
        vp = v_seq.reshape(b * n, ps, kvh, d)
        table = jnp.arange(b * n, dtype=jnp.int32).reshape(b, n)
        outs.append(paged_attention(q, kp, vp, table, lengths, interpret=True))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------- gather / scatter


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(4, 32), elems=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_page_gather_property(p, elems, seed):
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.normal(size=(p, elems)), jnp.float32)
    n = rng.integers(1, p + 1)
    ids = jnp.asarray(rng.choice(p, size=n, replace=False), jnp.int32)
    out = page_gather(pool, ids, interpret=True)
    np.testing.assert_allclose(out, page_gather_ref(pool, ids))


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(4, 32), elems=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_page_scatter_property(p, elems, seed):
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.normal(size=(p, elems)), jnp.float32)
    n = int(rng.integers(1, p + 1))
    ids = jnp.asarray(rng.choice(p, size=n, replace=False), jnp.int32)
    pages = jnp.asarray(rng.normal(size=(n, elems)), jnp.float32)
    ref = page_scatter_ref(pool, ids, pages)
    out = page_scatter(pool, ids, pages, interpret=True)
    np.testing.assert_allclose(out, ref)


def test_gather_scatter_roundtrip():
    """UFFDIO_COPY semantics: install then read back the exact page."""
    pool = jnp.zeros((8, 128), jnp.float32)
    ids = jnp.asarray([3, 5], jnp.int32)
    pages = jnp.asarray(np.random.default_rng(0).normal(size=(2, 128)),
                        jnp.float32)
    pool = page_scatter(pool, ids, pages, interpret=True)
    back = page_gather(pool, ids, interpret=True)
    np.testing.assert_allclose(back, pages)
