"""Property-based chaos tests (hypothesis): random seeded ChaosStore
schedules under concurrent readers/writers (DESIGN.md §17.6).

For ANY (seed, fault-rate, concurrency) draw, the resilient paging stack
must preserve three invariants:

  * byte-exact or raised — a read either returns exactly the bytes the
    thread's own mirror predicts or raises; never silently wrong data;
  * no slot leaks — after the storm drains and the region unmaps, every
    page-buffer slot is back on the free list;
  * stats parity — ``retries_ok <= retries`` on the store wrapper, the
    pager surfaces ``io_errors`` only if the chaos layer actually
    injected faults, and a zero-rate schedule surfaces nothing at all.

Writers are partitioned by page range (one disjoint span per thread), so
each thread's mirror is authoritative for its own span and the oracle
stays exact under real concurrency.
"""

import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import ChaosStore, HostArrayStore, UMapConfig, umap, uunmap

PAGE = 512
PAGES_PER_THREAD = 16


def _run_storm(seed: int, read_error_rate: float, torn_write_rate: float,
               threads: int, ops: int, slots: int):
    """Drive `threads` workers over disjoint page spans; return everything
    the invariant checks need."""
    npages = threads * PAGES_PER_THREAD
    base = (np.arange(npages * PAGE) % 251).astype(np.uint8)
    chaos = ChaosStore(HostArrayStore(base.copy()), seed=seed,
                       read_error_rate=read_error_rate,
                       torn_write_rate=torn_write_rate,
                       permanent_fraction=0.0)
    cfg = UMapConfig(page_size=PAGE, buffer_size=slots * PAGE,
                     resilient_io=True, io_retries=2,
                     retry_backoff_s=1e-4, retry_max_backoff_s=1e-3,
                     retry_deadline_s=2.0,
                     breaker_threshold=1000,   # rates, not outages: no trips
                     num_fillers=2, num_evictors=1, shards=2,
                     writeback_retries=2)
    region = umap(chaos, config=cfg)
    svc = region.service
    mirrors = [base[t * PAGES_PER_THREAD * PAGE:
                    (t + 1) * PAGES_PER_THREAD * PAGE].copy()
               for t in range(threads)]
    surfaced = [0] * threads
    wrong = [0] * threads

    def worker(t):
        rng = np.random.default_rng(seed * 101 + t)
        lo_page = t * PAGES_PER_THREAD
        mir = mirrors[t]
        for i in range(ops):
            p = int(rng.integers(0, PAGES_PER_THREAD))
            off = (lo_page + p) * PAGE
            moff = p * PAGE
            if rng.random() < 0.35:
                val = np.full(PAGE, int(rng.integers(0, 256)), np.uint8)
                try:
                    region.write(off, val)
                except OSError:
                    surfaced[t] += 1
                else:
                    mir[moff:moff + PAGE] = val
            else:
                try:
                    got = region.read(off, PAGE)
                except OSError:
                    surfaced[t] += 1
                else:
                    if not np.array_equal(got, mir[moff:moff + PAGE]):
                        wrong[t] += 1

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    # heal the store so the drain below is deterministic, then verify the
    # full mirror through the (now clean) paging path
    chaos.read_error_rate = 0.0
    chaos.torn_write_rate = 0.0
    svc.flush_region(region)
    final_wrong = 0
    for t in range(threads):
        lo = t * PAGES_PER_THREAD * PAGE
        got = region.read(lo, PAGES_PER_THREAD * PAGE)
        if not np.array_equal(got, mirrors[t]):
            final_wrong += 1
    rstats = region.store.resilience_stats()
    cstats = chaos.chaos_stats()
    svc_stats = svc.stats.snapshot()
    buffer = svc.buffer
    uunmap(region)
    return {
        "surfaced": sum(surfaced),
        "wrong": sum(wrong) + final_wrong,
        "rstats": rstats,
        "cstats": cstats,
        "svc_stats": svc_stats,
        "used_slots_after_unmap": buffer.used_slots,
    }


@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(min_value=0, max_value=2**16),
       read_error_rate=st.sampled_from([0.0, 0.02, 0.1]),
       torn_write_rate=st.sampled_from([0.0, 0.02]),
       threads=st.integers(min_value=2, max_value=3),
       slots=st.integers(min_value=4, max_value=12))
def test_chaos_storm_invariants(seed, read_error_rate, torn_write_rate,
                                threads, slots):
    out = _run_storm(seed, read_error_rate, torn_write_rate,
                     threads=threads, ops=60, slots=slots)
    # byte-exact or raised: no read ever returned wrong bytes
    assert out["wrong"] == 0, out
    # no slot leaks: unmap returned every buffer slot
    assert out["used_slots_after_unmap"] == 0, out
    # stats parity
    r, c, s = out["rstats"], out["cstats"], out["svc_stats"]
    assert r["retries_ok"] <= r["retries"]
    injected = (c["injected_read_errors"] + c["injected_write_errors"]
                + c["torn_writes"])
    if injected == 0:
        assert out["surfaced"] == 0 and s["io_errors"] == 0, out
    if out["surfaced"] > 0 or s["io_errors"] > 0:
        assert injected > 0, out
    if injected > 0:
        # every injected fault was either absorbed by a retry or surfaced
        # as a counted error somewhere — never silently dropped
        assert r["retries"] + s["io_errors"] + out["surfaced"] > 0, out


@settings(max_examples=3, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(min_value=0, max_value=2**16),
       slots=st.integers(min_value=4, max_value=8))
def test_zero_rate_schedule_is_fault_free(seed, slots):
    """The harness itself must not perturb a clean run: zero rates mean
    zero injections, zero surfaced errors, zero retries."""
    out = _run_storm(seed, 0.0, 0.0, threads=2, ops=40, slots=slots)
    assert out["wrong"] == 0
    assert out["surfaced"] == 0
    assert out["rstats"]["retries"] == 0
    assert out["svc_stats"]["io_errors"] == 0
    assert out["used_slots_after_unmap"] == 0
