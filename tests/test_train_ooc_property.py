"""Property-based OOC-state layout tests (hypothesis, DESIGN.md §18.2).

For ANY tree of fp32 leaf sizes and any page size, ``pack_tree`` must be
a lossless page-aligned layout (exact bytes back out, zero padding), and
the mv-interleaved moments encoding must round-trip — the two layout
facts the paged/resident bitwise-equivalence proof in test_train_ooc.py
rests on.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.train.paged_state import (
    interleave_moments,
    pack_tree,
    split_moments,
)


@settings(max_examples=40, deadline=None)
@given(sizes=st.lists(st.integers(1, 500), min_size=1, max_size=8),
       page_elems=st.sampled_from([16, 64, 256]),
       seed=st.integers(0, 2**16))
def test_pack_tree_roundtrip(sizes, page_elems, seed):
    rng = np.random.default_rng(seed)
    page = 4 * page_elems
    tree = {f"l{i}": rng.standard_normal(n).astype(np.float32)
            for i, n in enumerate(sizes)}
    buf, specs, treedef = pack_tree(tree, page)
    assert buf.nbytes % page == 0
    assert treedef == jax.tree_util.tree_structure(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    assert len(specs) == len(leaves)
    next_page = 0
    for leaf, spec in zip(leaves, specs):
        assert spec["first_page"] == next_page, "leaves must be adjacent"
        next_page += spec["npages"]
        lo = spec["first_page"] * page
        got = buf[lo:lo + spec["nbytes"]].view(np.float32)
        np.testing.assert_array_equal(got, leaf.reshape(-1))
        pad = buf[lo + spec["nbytes"]:lo + spec["npages"] * page]
        assert not pad.any(), "inter-leaf padding must be zero"


@settings(max_examples=40, deadline=None)
@given(shape=st.tuples(st.integers(1, 12), st.integers(1, 12)),
       seed=st.integers(0, 2**16))
def test_interleave_split_roundtrip(shape, seed):
    rng = np.random.default_rng(seed)
    m = {"w": rng.standard_normal(shape).astype(np.float32)}
    v = {"w": rng.standard_normal(shape).astype(np.float32)}
    mv = interleave_moments(m, v)["w"]
    assert mv.dtype == np.float32 and mv.size == 2 * m["w"].size
    # Element-interleaved [m0,v0,m1,v1,...]: one strictly ascending scan
    # covers both moments — the layout the sequential classifier sees.
    np.testing.assert_array_equal(mv[0::2], m["w"].reshape(-1))
    np.testing.assert_array_equal(mv[1::2], v["w"].reshape(-1))
    m2, v2 = split_moments(mv, shape)
    np.testing.assert_array_equal(m2, m["w"])
    np.testing.assert_array_equal(v2, v["w"])
