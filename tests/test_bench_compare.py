"""Bench regression gate (benchmarks/compare.py): noise-band judging
(property-tested with hypothesis when available), the mini-TOML bands
parser, v1/v2 result-file loading, golden-file schema validation for
every committed ``experiments/bench/*.json``, and the CLI end-to-end
(self-compare passes; injected out-of-band regression fails; improvement
never fails; vanished rows/metrics fail).
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import (  # noqa: E402
    BENCH_SCHEMA_VERSION,
    RESULTS_DIR,
    Row,
    load_rows,
    save_rows,
)
from benchmarks.compare import (  # noqa: E402
    IMPROVEMENT,
    OK,
    REGRESSION,
    Band,
    BandTable,
    DEFAULT_BANDS,
    compare_suite,
    judge,
    load_toml,
    main as compare_main,
    parse_mini_toml,
)


# ------------------------------------------------------------- judge (unit)


class TestJudge:
    def test_identical_is_ok(self):
        assert judge(100.0, 100.0, Band(0.1, 0.0, "lower")) == OK

    def test_within_band_both_directions(self):
        band = Band(0.2, 0.0, "lower")
        assert judge(100.0, 119.0, band) == OK
        assert judge(100.0, 81.0, band) == OK

    def test_regression_beyond_band_lower_is_better(self):
        assert judge(100.0, 121.0, Band(0.2, 0.0, "lower")) == REGRESSION

    def test_regression_beyond_band_higher_is_better(self):
        assert judge(100.0, 79.0, Band(0.2, 0.0, "higher")) == REGRESSION

    def test_improvement_never_fails(self):
        assert judge(100.0, 50.0, Band(0.2, 0.0, "lower")) == IMPROVEMENT
        assert judge(100.0, 150.0, Band(0.2, 0.0, "higher")) == IMPROVEMENT

    def test_ignore_direction_never_gates(self):
        band = Band(0.0, 0.0, "ignore")
        assert judge(100.0, 1e9, band) == OK
        assert judge(100.0, -1e9, band) == OK

    def test_abs_tol_covers_zero_baseline(self):
        assert judge(0.0, 1.0, Band(0.5, 2.0, "lower")) == OK
        assert judge(0.0, 3.0, Band(0.5, 2.0, "lower")) == REGRESSION

    def test_zero_baseline_zero_tol_any_increase_regresses(self):
        # the io_errors band: baseline 0, rel 0, abs 0
        band = Band(0.0, 0.0, "lower")
        assert judge(0.0, 0.0, band) == OK
        assert judge(0.0, 1.0, band) == REGRESSION

    def test_band_validation(self):
        with pytest.raises(ValueError):
            Band(direction="sideways")
        with pytest.raises(ValueError):
            Band(rel_tol=-0.1)


class TestJudgeProperties:
    """Hypothesis property tests for the noise-band logic (satellite):
    within the band there is never a false regression, beyond it the gate
    always fires, and an improvement never fails."""

    def test_properties(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        finite = st.floats(min_value=-1e9, max_value=1e9,
                           allow_nan=False, allow_infinity=False)
        tols = st.floats(min_value=0.0, max_value=10.0,
                         allow_nan=False, allow_infinity=False)
        directions = st.sampled_from(["lower", "higher"])

        @settings(max_examples=300, deadline=None)
        @given(baseline=finite, fresh=finite, rel=tols, abs_=tols,
               direction=directions)
        def prop(baseline, fresh, rel, abs_, direction):
            band = Band(rel, abs_, direction)
            verdict = judge(baseline, fresh, band)
            allowed = rel * abs(baseline) + abs_
            worse = (fresh - baseline) if direction == "lower" \
                else (baseline - fresh)
            if abs(fresh - baseline) <= allowed:
                # no false regression within the band — either direction
                assert verdict == OK
            elif worse > allowed:
                assert verdict == REGRESSION
            else:
                assert verdict == IMPROVEMENT
            # an improvement (better-direction move) never fails the gate
            if (direction == "lower" and fresh <= baseline) or \
                    (direction == "higher" and fresh >= baseline):
                assert verdict != REGRESSION
            # ignore never gates, whatever the values
            assert judge(baseline, fresh,
                         Band(rel, abs_, "ignore")) == OK

        prop()


# -------------------------------------------------------------- mini-TOML


class TestMiniToml:
    def test_tables_and_scalar_types(self):
        doc = parse_mini_toml(
            '# comment\n'
            '[default]\n'
            'rel_tol = 0.5\n'
            'abs_tol = 2\n'
            'direction = "lower"  \n'
            'flag = true\n'
            '\n'
            '[suite.fault_overhead.store_reads]\n'
            'rel_tol = 0.15   # trailing comment\n')
        assert doc["default"] == {"rel_tol": 0.5, "abs_tol": 2,
                                  "direction": "lower", "flag": True}
        assert doc["suite"]["fault_overhead"]["store_reads"] == \
            {"rel_tol": 0.15}

    def test_malformed_lines_raise(self):
        for bad in ("[unclosed\n", "no_equals_here\n", "k = unquoted str\n"):
            with pytest.raises(ValueError):
                parse_mini_toml(bad)

    def test_matches_tomllib_when_available(self):
        text = DEFAULT_BANDS.read_text()
        try:
            import tomllib
        except ModuleNotFoundError:
            pytest.skip("no tomllib on this interpreter")
        assert parse_mini_toml(text) == tomllib.loads(text)


class TestBandTable:
    def test_lookup_precedence(self):
        table = BandTable({
            "default": {"rel_tol": 0.5, "direction": "lower"},
            "metric": {"seconds": {"rel_tol": 0.3}},
            "suite": {"sort": {"seconds": {"rel_tol": 0.1}}},
        })
        assert table.lookup("sort", "seconds").rel_tol == 0.1
        assert table.lookup("bfs", "seconds").rel_tol == 0.3
        assert table.lookup("bfs", "unknown_metric").rel_tol == 0.5
        # metric-level entries inherit unset fields from the default
        assert table.lookup("bfs", "seconds").direction == "lower"

    def test_unknown_band_keys_rejected(self):
        with pytest.raises(ValueError):
            BandTable({"metric": {"seconds": {"typo_tol": 1.0}}})


# ------------------------------------------------------------ result files


class TestLoadRows:
    def test_v2_roundtrip(self, tmp_path):
        rows = [Row("w", "umap", 4096, 1.5, {"store_reads": 10})]
        path = save_rows("suite_x", rows, out_dir=tmp_path)
        doc = json.loads(path.read_text())
        assert doc["schema_version"] == BENCH_SCHEMA_VERSION
        assert doc["suite"] == "suite_x"
        loaded = load_rows(path)
        assert loaded == [{"workload": "w", "config": "umap",
                           "page_size": 4096, "seconds": 1.5,
                           "store_reads": 10}]

    def test_v1_bare_list_accepted(self, tmp_path):
        p = tmp_path / "old.json"
        p.write_text(json.dumps([{"workload": "w", "config": "c",
                                  "page_size": 1, "seconds": 0.5}]))
        assert load_rows(p)[0]["config"] == "c"

    def test_bad_version_and_shape_rejected(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema_version": 99, "rows": []}))
        with pytest.raises(ValueError, match="schema_version"):
            load_rows(p)
        p.write_text(json.dumps({"schema_version": 2, "rows": "nope"}))
        with pytest.raises(ValueError, match="list of row"):
            load_rows(p)
        p.write_text(json.dumps([{"workload": "w"}]))
        with pytest.raises(ValueError, match="missing"):
            load_rows(p)

    def test_env_var_redirects_results_dir(self, tmp_path, monkeypatch):
        monkeypatch.setenv("UMAP_BENCH_RESULTS_DIR", str(tmp_path))
        out = save_rows("redirected", [Row("w", "c", 1, 0.1)])
        assert out.parent == tmp_path


class TestCommittedGoldenFiles:
    """Golden-file schema validation for every committed baseline."""

    def _suites(self):
        return sorted(p for p in RESULTS_DIR.glob("*.json"))

    def test_eight_baselines_committed(self):
        assert {p.stem for p in self._suites()} == {
            "chaos", "fault_overhead", "fault_storm", "serve", "sort",
            "tiering", "train_ooc", "writeback"}

    def test_all_baselines_are_v2_and_loadable(self):
        for path in self._suites():
            doc = json.loads(path.read_text())
            assert doc["schema_version"] == BENCH_SCHEMA_VERSION, path
            assert doc["suite"] == path.stem, path
            rows = load_rows(path)
            assert rows, f"{path} has no rows"
            for row in rows:
                assert isinstance(row["seconds"], (int, float)), path

    def test_bands_file_parses_and_covers_headline_metrics(self):
        table = BandTable(load_toml(DEFAULT_BANDS))
        assert table.lookup("fault_overhead", "store_reads").rel_tol <= 0.15
        assert table.lookup("fault_storm", "best_speedup").direction == "higher"
        assert table.lookup("tiering", "io_errors").abs_tol == 0.0
        assert table.lookup("fault_storm", "lock_contended").direction == "ignore"
        assert table.lookup("serve", "isolation_ratio").direction == "lower"
        assert table.lookup("serve", "shared_savings_pages").direction == "higher"
        assert table.lookup("serve", "expired").abs_tol == 0.0

    def test_self_compare_of_committed_baselines_passes(self, capsys):
        assert compare_main([]) == 0
        assert "0 regressions" in capsys.readouterr().out


# ---------------------------------------------------------------- gate e2e


def _copy_baselines(dst: Path) -> None:
    dst.mkdir(parents=True, exist_ok=True)
    for p in RESULTS_DIR.glob("*.json"):
        (dst / p.name).write_text(p.read_text())


def _bump(dirpath: Path, suite: str, config: str, metric: str, factor: float):
    p = dirpath / f"{suite}.json"
    doc = json.loads(p.read_text())
    hit = False
    for row in doc["rows"]:
        if row["config"] == config and metric in row:
            row[metric] = type(row[metric])(row[metric] * factor)
            hit = True
    assert hit, f"no row {config} with {metric} in {suite}"
    p.write_text(json.dumps(doc))


class TestCompareCLI:
    def test_injected_20pct_regression_fails(self, tmp_path, capsys):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        _bump(fresh, "fault_overhead", "batch-on", "store_reads", 1.2)
        rc = compare_main(["--fresh", str(fresh)])
        assert rc == 1
        assert "REGRESSION" in capsys.readouterr().out

    def test_improvement_passes(self, tmp_path, capsys):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        _bump(fresh, "fault_overhead", "batch-on", "store_reads", 0.5)
        assert compare_main(["--fresh", str(fresh)]) == 0
        assert "improvement" in capsys.readouterr().out

    def test_ignored_metric_noise_passes(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        _bump(fresh, "fault_storm", "shards8", "lock_contended", 50.0)
        assert compare_main(["--fresh", str(fresh)]) == 0

    def test_missing_row_fails(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        p = fresh / "writeback.json"
        doc = json.loads(p.read_text())
        doc["rows"] = [r for r in doc["rows"] if r["config"] != "batched"]
        p.write_text(json.dumps(doc))
        assert compare_main(["--fresh", str(fresh)]) == 1

    def test_missing_metric_fails_unless_ignored(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        p = fresh / "tiering.json"
        doc = json.loads(p.read_text())
        for r in doc["rows"]:
            r.pop("slow_store_reads", None)    # gated metric vanished
            r.pop("lock_contended", None)      # (not present anyway)
        p.write_text(json.dumps(doc))
        assert compare_main(["--fresh", str(fresh)]) == 1

    def test_missing_suite_fails_without_smoke(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        (fresh / "sort.json").unlink()
        assert compare_main(["--fresh", str(fresh)]) == 1

    def test_smoke_limits_to_present_suites(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        for name in ("sort", "fault_overhead"):
            (fresh / f"{name}.json").unlink()
        assert compare_main(["--fresh", str(fresh), "--smoke"]) == 0

    def test_suites_subset_and_unknown(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        assert compare_main(["--fresh", str(fresh),
                             "--suites", "sort,tiering"]) == 0
        assert compare_main(["--fresh", str(fresh),
                             "--suites", "nope"]) == 2

    def test_report_written(self, tmp_path):
        fresh = tmp_path / "fresh"
        _copy_baselines(fresh)
        _bump(fresh, "fault_overhead", "batch-on", "store_reads", 1.2)
        report = tmp_path / "diff.md"
        assert compare_main(["--fresh", str(fresh),
                             "--report", str(report)]) == 1
        text = report.read_text()
        assert "Regressions (gate FAILED)" in text
        assert "store_reads" in text

    def test_update_copies_fresh_over_baseline(self, tmp_path):
        baseline = tmp_path / "base"
        fresh = tmp_path / "fresh"
        _copy_baselines(baseline)
        _copy_baselines(fresh)
        _bump(fresh, "fault_overhead", "batch-on", "store_reads", 1.5)
        assert compare_main(["--fresh", str(fresh),
                             "--baseline", str(baseline),
                             "--bands", str(DEFAULT_BANDS),
                             "--update"]) == 0
        doc = json.loads((baseline / "fault_overhead.json").read_text())
        row = next(r for r in doc["rows"] if r["config"] == "batch-on")
        assert row["store_reads"] == 433                   # 289 * 1.5
        # and a re-compare against the refreshed baseline is clean
        assert compare_main(["--fresh", str(fresh),
                             "--baseline", str(baseline),
                             "--bands", str(DEFAULT_BANDS)]) == 0

    def test_update_requires_fresh_dir(self):
        assert compare_main(["--update"]) == 2

    def test_bad_bands_file_is_usage_error(self, tmp_path):
        bad = tmp_path / "bad.toml"
        bad.write_text("[metric.seconds]\ntypo_tol = 1.0\n")
        assert compare_main(["--bands", str(bad)]) == 2


# ------------------------------------------------------------ compare_suite


class TestCompareSuite:
    def _bands(self):
        return BandTable({"default": {"rel_tol": 0.1, "direction": "lower"}})

    def test_new_rows_and_metrics_are_informational(self):
        base = [{"workload": "w", "config": "a", "page_size": 1,
                 "seconds": 1.0}]
        fresh = [{"workload": "w", "config": "a", "page_size": 1,
                  "seconds": 1.0, "new_metric": 5},
                 {"workload": "w", "config": "b", "page_size": 1,
                  "seconds": 9.9}]
        findings = compare_suite("s", base, fresh, self._bands())
        assert all(f.verdict != REGRESSION for f in findings)

    def test_row_identity_is_workload_config_pagesize(self):
        base = [{"workload": "w", "config": "a", "page_size": 4096,
                 "seconds": 1.0}]
        fresh = [{"workload": "w", "config": "a", "page_size": 8192,
                  "seconds": 1.0}]
        findings = compare_suite("s", base, fresh, self._bands())
        assert any(f.metric == "<row>" and f.verdict == REGRESSION
                   for f in findings)
