"""Tiered store + heat-driven migration (DESIGN.md §14).

Covers: TieredStore read-through/write-back semantics and per-tier batch
splitting (single-op coalescing preserved per tier), the transactional
promote/demote protocol (generation verify, pin refusal), the pager's
heat-driven migration engine end to end, application tier hints
(hot/cold/pin_fast through ``region.advise``), the mid-migration fault
storm byte-exactness acceptance check, error propagation through a tiered
region (FaultyStore on the slow tier), config/env parity for the
``UMAP_TIER_*`` knobs, and the checkpoint fast-tier opt-in.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    FaultyStore,
    HostArrayStore,
    RemoteStore,
    TieredStore,
    TierHint,
    UMapConfig,
    umap,
    uunmap,
)

PAGE = 4096
EXTENT = 4 * PAGE
NPAGES = 128


def _data(nbytes: int) -> np.ndarray:
    return (np.arange(nbytes, dtype=np.int64) % 251).astype(np.uint8)


def _tiered(fast_extents: int = 4, **kw) -> TieredStore:
    slow = HostArrayStore(_data(NPAGES * PAGE))
    fast = HostArrayStore(np.zeros(fast_extents * EXTENT, np.uint8))
    kw.setdefault("extent_size", EXTENT)
    kw.setdefault("promote_on_read", False)
    return TieredStore(fast, slow, **kw)


# ------------------------------------------------------------ store semantics


def test_read_through_and_residency_routing():
    ts = _tiered()
    ref = _data(NPAGES * PAGE)
    buf = np.empty(3 * PAGE, np.uint8)
    ts.read_into(PAGE, buf)
    assert np.array_equal(buf, ref[PAGE : 4 * PAGE])
    assert ts.promote(0)
    assert ts.resident_extents() == [0]
    slow_reads = ts.slow.num_reads
    ts.read_into(0, buf)                  # extent 0 resident: fast only
    assert np.array_equal(buf, ref[: 3 * PAGE])
    assert ts.slow.num_reads == slow_reads
    # spanning resident extent 0 -> non-resident extent 1 splits per tier
    span = np.empty(2 * PAGE, np.uint8)
    ts.read_into(3 * PAGE, span)
    assert np.array_equal(span, ref[3 * PAGE : 5 * PAGE])
    assert ts.slow.num_reads == slow_reads + 1


def test_write_back_dirty_extents_flush_to_slow():
    ts = _tiered()
    assert ts.promote(2)
    slow_writes = ts.slow.num_writes
    payload = np.full(100, 9, np.uint8)
    ts.write_from(2 * EXTENT + 10, payload)
    assert ts.slow.num_writes == slow_writes, "resident write stays in fast"
    assert ts.tier_stats()["dirty_extents"] == 1
    back = np.empty(100, np.uint8)
    ts.read_into(2 * EXTENT + 10, back)
    assert (back == 9).all()
    ts.flush()
    assert ts.tier_stats()["dirty_extents"] == 0
    check = np.empty(100, np.uint8)
    ts.slow.read_into(2 * EXTENT + 10, check)
    assert (check == 9).all()
    # non-resident write goes straight to slow (write-around)
    ts.write_from(5 * EXTENT, payload)
    check2 = np.empty(100, np.uint8)
    ts.slow.read_into(5 * EXTENT, check2)
    assert (check2 == 9).all()


def test_batch_ops_split_per_tier_preserve_coalescing():
    ts = _tiered(fast_extents=8)
    ref = _data(NPAGES * PAGE)
    assert ts.promote(1) and ts.promote(2)       # resident run [1,2]
    slow_reads = ts.slow.num_reads
    bufs = [np.empty(PAGE, np.uint8) for _ in range(6 * EXTENT // PAGE)]
    ts.read_into_batch(0, bufs)                  # extents 0..5
    assert np.array_equal(np.concatenate(bufs), ref[: 6 * EXTENT])
    # extents [0] and [3,4,5] are the two non-resident runs: exactly TWO
    # slow batched calls, not one per page/extent (coalescing preserved).
    assert ts.slow.num_reads == slow_reads + 2
    # batched write: extents 1-2 resident -> fast, 3 -> slow, one call each
    slow_writes = ts.slow.num_writes
    wbufs = [np.full(PAGE, 7, np.uint8) for _ in range(3 * EXTENT // PAGE)]
    ts.write_from_batch(EXTENT, wbufs)
    assert ts.slow.num_writes == slow_writes + 1
    out = np.empty(3 * EXTENT, np.uint8)
    ts.read_into(EXTENT, out)
    assert (out == 7).all()


def test_short_final_extent_and_eof_zero_fill():
    slow = HostArrayStore(_data(EXTENT + PAGE))  # 1.25 extents
    ts = TieredStore(HostArrayStore(np.zeros(2 * EXTENT, np.uint8)), slow,
                     extent_size=EXTENT, promote_on_read=False)
    assert ts.num_extents == 2
    assert ts.promote(1)                          # short extent promotes too
    buf = np.full(2 * PAGE, 7, np.uint8)
    got = ts.read_into(EXTENT, buf)
    assert got == PAGE
    assert np.array_equal(buf[:PAGE], _data(EXTENT + PAGE)[EXTENT:])
    assert (buf[PAGE:] == 0).all()


def test_promote_aborts_on_racing_write():
    ts = _tiered()
    orig = ts.slow.read_into

    def racing_read(offset, buf):
        n = orig(offset, buf)
        # A write lands between the staging copy and the commit: the
        # generation check must abort the promotion (torn-extent guard).
        ts.write_from(offset, np.full(8, 1, np.uint8))
        return n

    ts.slow.read_into = racing_read
    assert ts.promote(0) is False
    assert ts.migration_aborts == 1
    ts.slow.read_into = orig
    assert ts.promote(0) is True                  # clean retry succeeds


def test_promote_aborts_on_in_flight_write():
    """Review regression: a writer bumps the generation BEFORE its
    slow-tier I/O lands, so promote's commit must also refuse write-
    pinned extents — or it would publish the pre-write bytes."""
    ts = _tiered()
    orig = ts.slow.write_from_batch
    raced = {}

    def hook(offset, bufs):
        # Mid write-around (gen bumped, bytes not yet in slow): a promote
        # staged NOW would capture stale data — commit must abort.
        raced["promote"] = ts.promote(0)
        return orig(offset, bufs)

    ts.slow.write_from_batch = hook
    ts.write_from(0, np.full(100, 3, np.uint8))
    ts.slow.write_from_batch = orig
    assert raced["promote"] is False
    assert ts.migration_aborts == 1
    assert ts.promote(0) is True                  # quiesced: succeeds
    out = np.empty(100, np.uint8)
    ts.read_into(0, out)
    assert (out == 3).all(), "promoted copy must carry the racing write"


def test_flush_pins_extent_against_slot_recycling():
    """Review regression: flush's staging copy must pin the extent — a
    concurrent demote would free the slot (and a promote could reuse it
    for a different extent), corrupting the slow tier at commit."""
    ts = _tiered()
    assert ts.promote(0)
    ts.write_from(10, np.full(50, 9, np.uint8))     # extent 0 dirty
    raced = {}
    orig = ts.fast.read_into

    def racing_read(offset, buf):
        n = orig(offset, buf)
        # Mid-staging: demotion must be refused by the flush pin.
        raced["demote"] = ts.demote(0)
        return n

    ts.fast.read_into = racing_read
    ts.flush()
    ts.fast.read_into = orig
    assert raced["demote"] is False
    check = np.empty(50, np.uint8)
    ts.slow.read_into(10, check)
    assert (check == 9).all()


def test_flush_does_not_mark_clean_under_in_flight_write():
    """Review regression: flush's commit, like promote's, must refuse an
    extent with a write still in flight — gen is bumped before the write
    I/O lands, so the staging copy may be torn at an unchanged gen."""
    ts = _tiered()
    assert ts.promote(0)
    ts.write_from(10, np.full(50, 9, np.uint8))     # extent 0 dirty
    calls = {"n": 0}
    orig = ts.fast.read_into

    def hook(offset, buf):
        calls["n"] += 1
        with ts._lock:                 # deterministic stand-in for a
            if calls["n"] == 1:        # writer mid fast-tier I/O
                ts._wpins[0] = 1
            else:
                ts._wpins.pop(0, None)
        return orig(offset, buf)

    ts.fast.read_into = hook
    ts.flush()
    ts.fast.read_into = orig
    assert calls["n"] >= 2, "first commit must be refused and retried"
    assert ts.tier_stats()["dirty_extents"] == 0
    check = np.empty(50, np.uint8)
    ts.slow.read_into(10, check)
    assert (check == 9).all()


def test_demote_refuses_pins_and_pin_fast():
    ts = _tiered()
    assert ts.promote(0) and ts.promote(1)
    ts.pin_fast([0])
    assert ts.demote(0) is False                  # pin_fast hint
    assert ts.demote(1) is True
    ts.unpin_fast([0])
    assert ts.demote(0) is True


def test_from_config_uses_tier_budget():
    cfg = UMapConfig(tier_fast_bytes=4 * EXTENT, tier_extent_size=EXTENT)
    ts = TieredStore.from_config(HostArrayStore(_data(NPAGES * PAGE)), cfg)
    assert ts.num_fast_slots == 4 and ts.extent_size == EXTENT
    # Pager pairing: placement is the migration engine's job — inline
    # read-through promotion would amplify every warm-up miss (review fix).
    assert ts.promote_on_read is False
    with pytest.raises(ValueError):
        TieredStore.from_config(
            HostArrayStore(_data(PAGE)), UMapConfig())   # no budget set


# -------------------------------------------------------- migration engine


def _storm_cfg(**kw):
    kw.setdefault("page_size", PAGE)
    kw.setdefault("buffer_size", 8 * PAGE)   # below the hot set: re-faults
    kw.setdefault("num_fillers", 2)
    kw.setdefault("num_evictors", 1)
    kw.setdefault("tier_interval_s", 0.01)
    kw.setdefault("tier_decay", 0.9)
    return UMapConfig(**kw)


def _hammer(region, pages, rounds=40):
    ref = _data(NPAGES * PAGE)
    for _ in range(rounds):
        for p in pages:
            got = region.read(p * PAGE, PAGE)
            assert np.array_equal(got, ref[p * PAGE : (p + 1) * PAGE])


def _wait_resident(ts, extents, timeout=5.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if set(extents) <= set(ts.resident_extents()):
            return True
        time.sleep(0.02)
    return False


@pytest.mark.slow
def test_heat_driven_promotion_end_to_end():
    ts = _tiered(fast_extents=2)
    region = umap(ts, config=_storm_cfg())
    # Hot set: pages 0..7 = extents 0..1; buffer (8 pages) churns them.
    rng = np.random.default_rng(0)
    for _ in range(50):
        _hammer(region, range(8), rounds=1)
        region.read(int(rng.integers(16, NPAGES)) * PAGE, PAGE)  # cold noise
    assert _wait_resident(ts, [0, 1]), \
        f"hot extents not promoted: {ts.resident_extents()}"
    snap = region.stats()
    assert snap["tier_promotions"] >= 2
    # Promoted extents now absorb the hot faults: slow reads stop growing.
    slow_reads = ts.slow.num_reads
    _hammer(region, range(8), rounds=5)
    assert ts.slow.num_reads <= slow_reads + 2
    uunmap(region)


@pytest.mark.slow
def test_tier_hints_hot_cold_pin_fast():
    ts = _tiered(fast_extents=2)
    region = umap(ts, config=_storm_cfg())
    # hot: promote ahead of any observed access
    region.advise(tier_hint="hot", offset=2 * EXTENT, nbytes=2 * EXTENT)
    assert _wait_resident(ts, [2, 3])
    # cold: demote what the app is done with
    region.advise(tier_hint=TierHint.COLD, offset=2 * EXTENT, nbytes=EXTENT)
    deadline = time.time() + 5.0
    while 2 in ts.resident_extents() and time.time() < deadline:
        time.sleep(0.02)
    assert 2 not in ts.resident_extents()
    # pin_fast: resident AND immune to cold-driven demotion pressure
    region.advise(tier_hint="pin_fast", offset=0, nbytes=EXTENT)
    assert _wait_resident(ts, [0])
    _hammer(region, range(8, 16), rounds=30)      # heat up extents 2..3
    time.sleep(0.3)
    assert 0 in ts.resident_extents(), "pin_fast extent was demoted"
    uunmap(region)


@pytest.mark.slow
def test_cold_hint_retried_until_demotable():
    """Review regression: a cold hint whose demote is refused (extent
    pinned by an in-flight read) must be re-queued, not silently lost."""
    ts = _tiered()
    region = umap(ts, config=_storm_cfg())
    region.advise(tier_hint="hot", offset=0, nbytes=EXTENT)
    assert _wait_resident(ts, [0])
    with ts._lock:                      # deterministic stand-in for an
        ts._pins[0] = ts._pins.get(0, 0) + 1   # in-flight read's pin
    region.advise(tier_hint="cold", offset=0, nbytes=EXTENT)
    time.sleep(0.15)                    # several engine cycles
    assert 0 in ts.resident_extents(), "demote must refuse a pinned extent"
    with ts._lock:
        ts._pins.pop(0)
    deadline = time.time() + 5.0
    while 0 in ts.resident_extents() and time.time() < deadline:
        time.sleep(0.02)
    assert 0 not in ts.resident_extents(), "re-queued cold hint never drained"
    uunmap(region)


def test_tier_hint_validation():
    region = umap(HostArrayStore(_data(8 * PAGE)),
                  config=UMapConfig(page_size=PAGE, buffer_size=4 * PAGE))
    with pytest.raises(ValueError):
        region.advise(tier_hint="hot")            # not a tiered region
    with pytest.raises(ValueError):
        region.advise()                           # no advice at all
    uunmap(region)
    ts_region = umap(_tiered(), config=_storm_cfg())
    with pytest.raises(ValueError):
        ts_region.advise(tier_hint="lukewarm")    # unknown hint string
    with pytest.raises(IndexError):
        # end past the region must raise, not silently clamp (review fix)
        ts_region.advise(tier_hint="hot",
                         offset=ts_region.size - 10, nbytes=1000)
    uunmap(ts_region)


@pytest.mark.slow
def test_mid_migration_fault_storm_byte_exact():
    """The tentpole acceptance check: concurrent faults racing promotions/
    demotions never observe a torn extent."""
    ts = _tiered(fast_extents=2)
    region = umap(ts, config=_storm_cfg(shards=4, buffer_size=16 * PAGE))
    ref = _data(NPAGES * PAGE)
    errors: list = []
    stop = threading.Event()

    def reader(tid):
        rng = np.random.default_rng(tid)
        while not stop.is_set():
            if rng.random() < 0.7:
                p = int(rng.integers(0, 8))       # hot: drives migration
            else:
                p = int(rng.integers(8, NPAGES))
            got = region.read(p * PAGE, PAGE)
            if not np.array_equal(got, ref[p * PAGE : (p + 1) * PAGE]):
                errors.append(p)
                return

    def hinter():
        # Adversarial churn: flip tier hints while readers fault.
        for i in range(20):
            region.advise(tier_hint="hot" if i % 2 else "cold",
                          offset=0, nbytes=2 * EXTENT)
            time.sleep(0.02)

    ts_threads = [threading.Thread(target=reader, args=(t,)) for t in range(4)]
    ts_threads.append(threading.Thread(target=hinter))
    [t.start() for t in ts_threads]
    time.sleep(1.5)
    stop.set()
    [t.join(timeout=10.0) for t in ts_threads]
    assert not errors, f"torn reads on pages {errors[:5]}"
    st = region.stats()
    assert st["tier_promotions"] + st["tier_demotions"] > 0, \
        "storm never exercised a migration"
    uunmap(region)


# ------------------------------------------------- error propagation (§14.4)


def test_tiered_region_propagates_slow_tier_failure():
    slow = FaultyStore(HostArrayStore(_data(NPAGES * PAGE)),
                       fail_after_reads=0)
    ts = TieredStore(HostArrayStore(np.zeros(4 * EXTENT, np.uint8)), slow,
                     extent_size=EXTENT, promote_on_read=False)
    region = umap(ts, config=_storm_cfg())
    with pytest.raises(IOError):
        region.read(0, PAGE)
    assert region.stats()["io_errors"] >= 1
    slow.fail_after_reads = None
    assert np.array_equal(region.read(0, PAGE), _data(PAGE))
    uunmap(region)


def test_promote_failure_returns_slot_and_engine_survives():
    slow = FaultyStore(HostArrayStore(_data(NPAGES * PAGE)),
                       fail_after_reads=0, fail_count=1)
    ts = TieredStore(HostArrayStore(np.zeros(2 * EXTENT, np.uint8)), slow,
                     extent_size=EXTENT, promote_on_read=False)
    with pytest.raises(OSError):
        ts.promote(0)
    assert ts.free_fast_slots() == 2, "failed promote leaked its fast slot"
    assert ts.promote(0) is True


# ----------------------------------------------------------- config / env


def test_tier_env_knobs():
    cfg = UMapConfig.from_env(env={
        "UMAP_TIER_FAST_BYTES": "1M",
        "UMAP_TIER_EXTENT": "64K",
        "UMAP_TIER_INTERVAL_MS": "100",
        "UMAP_TIER_DECAY": "0.5",
        "UMAP_TIER_PROMOTE_HEAT": "4",
        "UMAP_TIER_MAX_MIGRATIONS": "2",
        "UMAP_WRITEBACK_RETRIES": "5",
    })
    assert cfg.tier_fast_bytes == 1 << 20
    assert cfg.tier_extent_size == 64 * 1024
    assert cfg.tier_interval_s == pytest.approx(0.1)
    assert cfg.tier_decay == 0.5
    assert cfg.tier_promote_heat == 4.0
    assert cfg.tier_max_migrations == 2
    assert cfg.writeback_retries == 5


def test_tier_config_validation():
    with pytest.raises(ValueError):
        UMapConfig(tier_decay=1.0)
    with pytest.raises(ValueError):
        UMapConfig(tier_promote_heat=0)
    with pytest.raises(ValueError):
        UMapConfig(tier_interval_s=0)
    with pytest.raises(ValueError):
        UMapConfig(writeback_retries=0)
    with pytest.raises(ValueError):
        TieredStore(HostArrayStore(np.zeros(PAGE, np.uint8)),
                    HostArrayStore(_data(NPAGES * PAGE)),
                    extent_size=2 * PAGE)          # budget < one extent


# ------------------------------------------------ weight-pager opt-in


@pytest.mark.slow
def test_region_layer_source_pin_fast_layers():
    pytest.importorskip("jax")
    from repro.serve.weight_pager import RegionLayerSource, pack_layer_arrays

    layers = [np.full((EXTENT // 4,), i, np.float32) for i in range(4)]
    buf, specs = pack_layer_arrays(layers, page_size=PAGE)
    ts = TieredStore(HostArrayStore(np.zeros(4 * EXTENT, np.uint8)),
                     HostArrayStore(buf.copy()), extent_size=EXTENT,
                     promote_on_read=False)
    region = umap(ts, config=UMapConfig(page_size=PAGE,
                                        buffer_size=32 * PAGE))
    src = RegionLayerSource(region, specs, pin_fast_layers=[0])
    spec = specs[0]
    first_ext = (spec["first_page"] * PAGE) // EXTENT
    last_ext = ((spec["first_page"] + spec["npages"]) * PAGE - 1) // EXTENT
    want = list(range(first_ext, last_ext + 1))
    assert _wait_resident(ts, want), \
        f"pinned layer extents not promoted: {ts.resident_extents()}"
    assert set(want) <= set(ts.pinned_fast_extents())
    out = np.asarray(src[0])
    assert np.array_equal(out, layers[0])
    uunmap(region)


def test_region_layer_source_pin_fast_requires_tiered():
    pytest.importorskip("jax")
    from repro.serve.weight_pager import RegionLayerSource, pack_layer_arrays

    layers = [np.ones((PAGE // 4,), np.float32)]
    buf, specs = pack_layer_arrays(layers, page_size=PAGE)
    region = umap(HostArrayStore(buf.copy()),
                  config=UMapConfig(page_size=PAGE, buffer_size=8 * PAGE))
    with pytest.raises(ValueError):
        RegionLayerSource(region, specs, pin_fast_layers=[0])
    uunmap(region)


# --------------------------------------------------- checkpoint opt-in


def test_checkpointer_tiered_fast_restore():
    jax = pytest.importorskip("jax")
    from repro.ckpt.checkpoint import AsyncCheckpointer, restore_tree_from_store

    slow_inner = HostArrayStore(np.zeros(64 * EXTENT, np.uint8))
    slow = RemoteStore(slow_inner, latency_s=1e-4)
    ck = AsyncCheckpointer("/tmp/unused_tier_ckpt", store=slow,
                           tier_fast_bytes=8 * EXTENT)
    assert isinstance(ck.store, TieredStore)
    tree = {"w": np.arange(2048, dtype=np.float32),
            "b": np.ones(256, np.float32)}
    ck.save_async(1, tree)
    ck.flush()
    manifest = ck.store_manifest
    assert manifest is not None and manifest["step"] == 1
    # Durability: the image reached the SLOW tier through the flush.
    assert slow_inner.bytes_written >= 2048 * 4
    # The fresh image is fast-tier resident (promote_on_write), so the
    # restore reads host memory, not the remote tier.
    slow_reads = slow.num_reads
    out = restore_tree_from_store(ck.store, manifest, tree)
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["b"], tree["b"])
    assert slow.num_reads == slow_reads, "restore should hit the fast tier"
    # Review regression: the promise must survive past the first save —
    # the writer demotes the target half's stale extents, so save 2 (the
    # OTHER double-buffer half) promotes too and restores fast as well.
    tree2 = {"w": tree["w"] * 2, "b": tree["b"] * 3}
    ck.save_async(2, tree2)
    ck.flush()
    manifest2 = ck.store_manifest
    assert manifest2["step"] == 2 and manifest2["offset"] != manifest["offset"]
    slow_reads = slow.num_reads
    out2 = restore_tree_from_store(ck.store, manifest2, tree2)
    assert np.array_equal(out2["w"], tree2["w"])
    assert slow.num_reads == slow_reads, \
        "second save's restore should hit the fast tier too"
    ck.close()
