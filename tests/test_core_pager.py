"""Behavioral tests for the paging service (paper §3.1–3.6)."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    HostArrayStore,
    PagingService,
    RemoteStore,
    UMapConfig,
    umap,
    uunmap,
)


def make_region(nbytes=256 * 1024, page_size=4096, slots=16, **cfg_kw):
    data = (np.arange(nbytes) % 251).astype(np.uint8)
    store = HostArrayStore(data.copy())
    cfg = UMapConfig(page_size=page_size, buffer_size=slots * page_size,
                     num_fillers=4, num_evictors=2, **cfg_kw)
    return umap(store, config=cfg), data, store


def test_demand_paging_correctness():
    r, data, _ = make_region()
    try:
        for off, n in [(0, 10), (4090, 100), (100_000, 33), (256 * 1024 - 5, 5)]:
            assert np.array_equal(r.read(off, n), data[off : off + n])
    finally:
        uunmap(r)


def test_write_read_write_back():
    r, data, store = make_region()
    try:
        r.write(7000, np.full(9000, 42, np.uint8))     # spans 3+ pages
        assert (r.read(7000, 9000) == 42).all()
        r.flush()
        chk = np.empty(9000, np.uint8)
        store.read_into(7000, chk)
        assert (chk == 42).all()
    finally:
        uunmap(r)


def test_eviction_under_capacity_pressure():
    # region is 64 pages, buffer is 16 slots -> must evict
    r, data, store = make_region(nbytes=64 * 4096, slots=16)
    try:
        for pno in range(64):
            out = r.read(pno * 4096, 4096)
            assert np.array_equal(out, data[pno * 4096 : (pno + 1) * 4096])
        st = r.stats()
        assert st["evictions"] >= 64 - 16
        assert r.service.buffer.used_slots <= 16
    finally:
        uunmap(r)


def test_dirty_eviction_writes_back():
    r, data, store = make_region(nbytes=64 * 4096, slots=8)
    try:
        r.write(0, np.full(4096, 9, np.uint8))  # dirty page 0
        for pno in range(1, 64):                # push page 0 out
            r.read(pno * 4096, 4096)
        chk = np.empty(4096, np.uint8)
        store.read_into(0, chk)
        assert (chk == 9).all(), "dirty page was evicted without write-back"
    finally:
        uunmap(r)


def test_concurrent_readers_consistent():
    r, data, _ = make_region(nbytes=512 * 1024, slots=32)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        for _ in range(200):
            off = int(rng.integers(0, 512 * 1024 - 64))
            out = r.read(off, 64)
            if not np.array_equal(out, data[off : off + 64]):
                errors.append(off)

    try:
        ts = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errors, f"inconsistent reads at {errors[:5]}"
    finally:
        uunmap(r)


def test_shared_service_multi_region_isolation():
    """One buffer serves all regions (paper §3.3); data must not cross."""
    cfg = UMapConfig(page_size=4096, buffer_size=8 * 4096, num_fillers=4, num_evictors=2)
    svc = PagingService(cfg)
    a_data = np.full(64 * 4096, 1, np.uint8)
    b_data = np.full(64 * 4096, 2, np.uint8)
    ra = umap(HostArrayStore(a_data), service=svc)
    rb = umap(HostArrayStore(b_data), service=svc)
    try:
        for pno in range(64):
            assert (ra.read(pno * 4096, 128) == 1).all()
            assert (rb.read(pno * 4096, 128) == 2).all()
    finally:
        ra.close()
        rb.close()
        svc.close()


def test_load_balancing_multiple_fillers_engaged():
    """Work-stealing queue: with slow I/O, several fillers take fills (§3.3)."""
    nbytes = 64 * 4096
    inner = HostArrayStore((np.arange(nbytes) % 251).astype(np.uint8))
    store = RemoteStore(inner, latency_s=2e-3, bandwidth_Bps=1e9)
    cfg = UMapConfig(page_size=4096, buffer_size=64 * 4096, num_fillers=8, num_evictors=1)
    r = umap(store, config=cfg)
    try:
        threads = [
            threading.Thread(target=lambda lo: [r.read(p * 4096, 64) for p in range(lo, lo + 16)],
                             args=(lo,))
            for lo in (0, 16, 32, 48)
        ]
        [t.start() for t in threads]
        [t.join() for t in threads]
        fills = r.stats()["per_filler_fills"]
        assert sum(fills.values()) >= 64
        assert len(fills) >= 2, f"only one filler engaged: {fills}"
    finally:
        uunmap(r)


def test_prefetch_arbitrary_pages():
    r, data, _ = make_region(nbytes=256 * 4096, slots=64)
    try:
        wanted = [200, 3, 77, 150, 9]          # deliberately irregular (§3.6)
        r.prefetch_pages(wanted)
        deadline = time.time() + 2.0
        while r.service.resident_pages() < len(wanted) and time.time() < deadline:
            time.sleep(0.005)
        st0 = r.stats()
        for pno in wanted:
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        st = r.stats()
        assert st["prefetch_fills"] >= len(wanted)
        assert st["prefetch_hits"] >= len(wanted)
        assert st["demand_faults"] == st0["demand_faults"], "prefetched pages still faulted"
    finally:
        uunmap(r)


def test_readahead_reduces_demand_faults():
    r0, _, _ = make_region(nbytes=128 * 4096, slots=64, read_ahead=0)
    r8, _, _ = make_region(nbytes=128 * 4096, slots=64, read_ahead=8)
    try:
        for r in (r0, r8):
            for pno in range(128):
                r.read(pno * 4096, 4096)
        f0 = r0.stats()["demand_faults"]
        f8 = r8.stats()["demand_faults"]
        assert f8 < f0, f"readahead did not reduce faults: {f8} vs {f0}"
    finally:
        uunmap(r0)
        uunmap(r8)


def test_watermark_flush_bounds_dirty_pages():
    r, _, store = make_region(nbytes=64 * 4096, slots=32,
                              evict_high_water=0.5, evict_low_water=0.25)
    try:
        for pno in range(32):
            r.write(pno * 4096, np.full(4096, pno, np.uint8))
            time.sleep(0.002)  # give the monitor a chance to run
        deadline = time.time() + 3.0
        while r.service.dirty_ratio() > 0.5 and time.time() < deadline:
            time.sleep(0.01)
        assert r.service.dirty_ratio() <= 0.60, "watermark flusher never engaged"
        assert r.stats()["watermark_flushes"] >= 1
        assert r.stats()["writebacks"] >= 1
    finally:
        uunmap(r)


def test_mmap_compat_mode_synchronous_and_heuristic_readahead():
    nbytes = 128 * 4096
    data = (np.arange(nbytes) % 251).astype(np.uint8)
    cfg = UMapConfig.mmap_baseline(buffer_size=64 * 4096)
    r = umap(HostArrayStore(data.copy()), config=cfg)
    try:
        assert len(r.service._fillers) == 0      # no async fillers in mmap mode
        # sequential scan: heuristic readahead should kick in
        for pno in range(64):
            assert np.array_equal(r.read(pno * 4096, 4096),
                                  data[pno * 4096 : (pno + 1) * 4096])
        st = r.stats()
        assert st["prefetch_fills"] > 0, "heuristic readahead never engaged"
        assert st["demand_faults"] < 64
    finally:
        uunmap(r)


def test_fill_callback_plugin():
    """Paper §4: app-registered fault resolver (FITS-handler analogue)."""
    calls = []

    def resolver(page_no, buf):
        calls.append(page_no)
        buf[:] = page_no % 256

    nbytes = 16 * 4096
    cfg = UMapConfig(page_size=4096, buffer_size=8 * 4096, num_fillers=2,
                     num_evictors=1)
    r = umap(HostArrayStore(np.zeros(nbytes, np.uint8)), config=cfg,
             fill_callback=resolver)
    try:
        assert (r.read(5 * 4096, 100) == 5).all()
        assert (r.read(15 * 4096, 100) == 15).all()
        assert 5 in calls and 15 in calls
    finally:
        uunmap(r)


def test_uunmap_flushes_and_unregisters():
    data = np.zeros(16 * 4096, np.uint8)
    store = HostArrayStore(data)
    cfg = UMapConfig(page_size=4096, buffer_size=8 * 4096, num_fillers=2, num_evictors=1)
    r = umap(store, config=cfg)
    r.write(0, np.full(4096, 3, np.uint8))
    uunmap(r)
    chk = np.empty(4096, np.uint8)
    store.read_into(0, chk)
    assert (chk == 3).all()


def test_page_size_is_transfer_granularity():
    """UMap page defines the finest data-movement granularity (§3.6)."""
    for ps in (4096, 65536):
        nbytes = 32 * 65536
        store = HostArrayStore(np.zeros(nbytes, np.uint8))
        cfg = UMapConfig(page_size=ps, buffer_size=16 * 65536,
                         num_fillers=2, num_evictors=1)
        r = umap(store, config=cfg)
        try:
            r.read(0, 1)   # 1-byte touch moves exactly one page
            assert store.bytes_read == ps
        finally:
            uunmap(r)
