"""Chunked-vs-sequential oracles for the recurrent cores (ssm / xlstm) and
the chunked attention path vs dense attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import AttnSpec, attention, decode_attention
from repro.models.ssm import (
    selective_scan,
    selective_scan_decode,
    selective_scan_ref,
)
from repro.models.xlstm import mlstm_chunked, mlstm_ref


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(3, 60), d=st.sampled_from([4, 8]),
    n=st.sampled_from([2, 4]), cs=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_selective_scan_matches_ref(s, d, n, cs, seed):
    rng = np.random.default_rng(seed)
    b = 2
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32))
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y_ref, h_ref = selective_scan_ref(x, dt, B, C, A, D)
    y, h = selective_scan(x, dt, B, C, A, D, chunk_size=cs)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(h, h_ref, atol=5e-4, rtol=5e-3)


def test_selective_scan_decode_chain():
    """Sequential decode steps == full-sequence scan."""
    rng = np.random.default_rng(0)
    b, s, d, n = 2, 10, 4, 4
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32))
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y_ref, _ = selective_scan_ref(x, dt, B, C, A, D)
    h = jnp.zeros((b, d, n), jnp.float32)
    ys = []
    for t in range(s):
        y, h = selective_scan_decode(x[:, t], dt[:, t], B[:, t], C[:, t], A, D, h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=5e-4, rtol=5e-3)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(3, 50), cs=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlstm_chunked_matches_ref(s, cs, seed):
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 2, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    ig = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)
    fg = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32) + 2.0
    y_ref, st_ref = mlstm_ref(q, k, v, ig, fg)
    y, st_ = mlstm_chunked(q, k, v, ig, fg, chunk_size=cs)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(st_[0], st_ref[0], atol=1e-3, rtol=1e-2)


@pytest.mark.parametrize("causal_skip", [False, True])
@pytest.mark.parametrize("window", [None, 8])
def test_chunked_attention_matches_dense(causal_skip, window):
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    dense = attention(q, k, v, AttnSpec(causal=True, window=window,
                                        impl="dense"), pos, pos)
    chunked = attention(q, k, v, AttnSpec(causal=True, window=window,
                                          impl="chunked", chunk_size=16,
                                          causal_skip=causal_skip), pos, pos)
    np.testing.assert_allclose(chunked, dense, atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_cache_window():
    """Ring cache + window mask == dense attention over the window."""
    rng = np.random.default_rng(1)
    b, h, d, W = 1, 2, 8, 8
    S_total = 20
    k_all = jnp.asarray(rng.normal(size=(b, S_total, h, d)), jnp.float32)
    v_all = jnp.asarray(rng.normal(size=(b, S_total, h, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    cur = S_total - 1
    # ring cache holding the last W tokens at slot = pos % W
    kc = jnp.zeros((b, W, h, d), jnp.float32)
    vc = jnp.zeros((b, W, h, d), jnp.float32)
    pos_arr = jnp.full((b, W), -1, jnp.int32)
    for p in range(S_total):
        kc = kc.at[:, p % W].set(k_all[:, p])
        vc = vc.at[:, p % W].set(v_all[:, p])
        pos_arr = pos_arr.at[:, p % W].set(p)
    out = decode_attention(q, kc, vc, pos_arr,
                           jnp.full((b,), cur, jnp.int32), window=W)
    # dense reference over the last W positions
    pos = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32), (b, S_total))
    ref = attention(q, k_all, v_all,
                    AttnSpec(causal=True, window=W, impl="dense"),
                    jnp.full((b, 1), cur, jnp.int32), pos)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_vjp_gradients_match_dense():
    """Custom-VJP chunked attention gradients == dense-attention gradients."""
    rng = np.random.default_rng(3)
    b, s, h, kvh, d = 2, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    tgt = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)

    def loss(impl):
        def f(q, k, v):
            o = attention(q, k, v, AttnSpec(causal=True, window=None,
                                            impl=impl, chunk_size=8),
                          pos, pos)
            return jnp.sum((o.astype(jnp.float32) - tgt) ** 2)
        return f

    ld, gd = jax.value_and_grad(loss("dense"), argnums=(0, 1, 2))(q, k, v), None
    lc = jax.value_and_grad(loss("chunked"), argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(ld[0]), float(lc[0]), rtol=1e-5)
    for a, b_ in zip(ld[1], lc[1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-3)


def test_flash_vjp_gradients_window():
    rng = np.random.default_rng(4)
    b, s, h, d = 1, 24, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def loss(impl):
        def f(q, k, v):
            o = attention(q, k, v, AttnSpec(causal=True, window=6, impl=impl,
                                            chunk_size=8), pos, pos)
            return jnp.sum(o.astype(jnp.float32) ** 2)
        return f

    gd = jax.grad(loss("dense"), argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(loss("chunked"), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gd, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-3)
