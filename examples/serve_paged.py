"""Serving driver: continuous batching over the paged KV cache.

Demonstrates the full UMap-at-the-KV-level story: page-pool allocation
(free-list), admission watermarks on pool occupancy, per-sequence page
tables driving the decode step, sliding-window page eviction accounting,
and straggler requeue — while generating real tokens from a reduced
SmolLM-family model and cross-checking a sample against unbatched decode.

Run:  PYTHONPATH=src python examples/serve_paged.py [--requests 12]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs.registry import get_smoke_config
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(max_batch=4, page_size=args.page_size, num_pages=256,
                        max_pages_per_seq=32, prefill_bucket=16,
                        admit_high_water=0.85, admit_low_water=0.60)
    eng = ServeEngine(cfg, params, ecfg)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        L = int(rng.integers(4, 14))
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, size=L).astype(np.int32),
            max_new_tokens=args.max_new,
            deadline_s=30.0))
    eng.run_until_drained(max_steps=2000)
    dt = time.time() - t0

    done = len(eng.finished)
    toks = sum(len(r.generated) for r in eng.finished)
    print(f"served {done}/{args.requests} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s)")
    print("engine stats:", eng.stats)
    print(f"pool: {eng.allocator.used_pages} used / "
          f"{eng.allocator.num_pages} pages "
          f"(page = {args.page_size} tokens)")

    # cross-check one request against unbatched decode
    req = eng.finished[0]
    toks_ref = list(req.prompt)
    cache = M.init_cache(cfg, 1, 128)
    _, cache = M.prefill(cfg, params,
                         {"tokens": jnp.asarray([toks_ref[:-1]], jnp.int32)},
                         cache)
    cur = len(toks_ref) - 1
    out = []
    for _ in range(args.max_new):
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.asarray([toks_ref[-1]], jnp.int32),
            jnp.asarray([cur], jnp.int32))
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        toks_ref.append(nxt)
        cur += 1
    assert out == req.generated, "batched paged decode diverged from reference"
    print("paged-decode cross-check OK")


if __name__ == "__main__":
    main()
