"""End-to-end training driver: SmolLM-family model on an out-of-core token
shard, with async checkpointing and restart (deliverable b).

The default invocation trains a reduced SmolLM config for a few hundred steps
on synthetic data streamed through a UMap region (real demand paging +
readahead on the input path).  ``--arch smollm-135m --full`` selects the true
135M configuration (CPU-feasible but slow; the production path is the pjit
launcher in repro.launch).

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
"""

import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.core import FileStore, UMapConfig
from repro.data.pipeline import lm_batches
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="true 135M config instead of the reduced one")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = (get_config("smollm-135m") if args.full
           else get_smoke_config("smollm-135m"))

    # ---- synthetic token shard on disk, streamed through a UMap region ----
    tmp = Path(tempfile.mkdtemp(prefix="smollm_data_"))
    shard = tmp / "tokens.bin"
    rng = np.random.default_rng(0)
    need = args.steps * args.batch * (args.seq + 1) + 1024
    # skewed unigram distribution -> the model has something to learn
    v_eff = min(256, cfg.vocab_size)          # stay inside the smoke vocab
    probs = 1.0 / np.arange(1, v_eff + 1)
    probs /= probs.sum()
    tokens = rng.choice(v_eff, size=need, p=probs).astype(np.int32)
    tokens.tofile(shard)
    store = FileStore(str(shard))
    loader, reader = lm_batches(
        store, args.batch, args.seq,
        config=UMapConfig(page_size=256 * 1024, buffer_size=4 << 20,
                          num_fillers=2, num_evictors=1, read_ahead=4,
                          eviction_policy="swa"))

    # ---- trainer with async checkpoints + restart ----
    tcfg = TrainerConfig(
        train=TrainConfig(
            optimizer=AdamWConfig(learning_rate=3e-4, warmup_steps=20,
                                  total_steps=args.steps),
            loss_chunk=args.seq),
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir or str(tmp / "ckpt"),
        ckpt_every=max(10, args.steps // 4),
        log_every=max(1, args.steps // 10),
    )
    trainer = Trainer(cfg, tcfg)
    trainer.install_preemption_handler()
    resumed = trainer.try_resume()
    print(f"resumed={resumed} from step {trainer.step}")

    result = trainer.fit(loader)
    print(f"finished at step {result['final_step']}")
    first = result["history"][0]["loss"]
    last = result["history"][-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({result['history'][-1]['tokens_per_s']:.0f} tok/s)")
    print("data-pipeline stats:",
          {k: v for k, v in reader.stats().items() if k != "per_filler_fills"})
    reader.close()
    assert last < first, "model failed to learn"
    print("train_smollm OK")


if __name__ == "__main__":
    main()
