"""The paper's flagship workload as a standalone example: umapsort.

Sorts a disk file far larger than the permitted page buffer, comparing the
mmap-semantics baseline against UMap with the paper's recommended large-page
configuration — then prints the observed speedup (paper Fig 2: 2.5x at 8 MiB
pages on NVMe).

Run:  PYTHONPATH=src python examples/out_of_core_sort.py [--mb 64]
"""

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import FileStore, UMapConfig
from benchmarks.bench_sort import _make_dataset, _sort_through_region


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mb", type=int, default=64)
    ap.add_argument("--buffer-mb", type=int, default=16)
    args = ap.parse_args()

    tmp = Path(tempfile.mkdtemp(prefix="umapsort_"))
    src = tmp / "data.bin"
    n_bytes = args.mb * 1024 * 1024
    buffer = args.buffer_mb * 1024 * 1024

    results = {}
    for name, cfg in (
        ("mmap (4K pages, sync faults)", UMapConfig.mmap_baseline(buffer)),
        ("umap (1M pages, 8 fillers)", UMapConfig(
            page_size=1024 * 1024, buffer_size=buffer, num_fillers=8,
            num_evictors=4, read_ahead=2)),
    ):
        _make_dataset(src, n_bytes)
        t0 = time.perf_counter()
        _sort_through_region(src, cfg, n_bytes)
        dt = time.perf_counter() - t0
        results[name] = dt
        print(f"{name:34s} {dt:7.2f}s")

    base, tuned = list(results.values())
    print(f"\nUMap speedup over mmap baseline: {base / tuned:.2f}x "
          f"(paper Fig 2: 2.5x)")
    # verify sortedness of the first run region
    arr = np.fromfile(src, np.int64, count=min(n_bytes // 8, 1 << 20))
    runs_desc = np.all(np.diff(arr[: buffer // 16]) <= 0)
    print("first run descending:", bool(runs_desc))


if __name__ == "__main__":
    main()
