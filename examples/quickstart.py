"""Quickstart: UMap regions in five minutes.

Creates a disk-backed region, demonstrates demand paging, app-driven
prefetch, dirty watermark flushing, and the page-size advisor — the paper's
API surface end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
from pathlib import Path

import numpy as np

from repro.core import (
    FileStore,
    PageSizeAdvisor,
    StoreProfile,
    UMapConfig,
    WorkloadProfile,
    umap,
    uunmap,
)


def main() -> None:
    tmp = Path(tempfile.mkdtemp(prefix="umap_quickstart_"))
    path = tmp / "data.bin"

    # 1. a 64 MiB file on disk, far bigger than the page buffer we'll allow
    n = 8 * 1024 * 1024
    np.arange(n, dtype=np.int64).tofile(path)
    store = FileStore(str(path))

    # 2. map it with an 8 MiB buffer of 256 KiB UMap pages (umap() ~ mmap())
    cfg = UMapConfig(page_size=256 * 1024, buffer_size=8 * 1024 * 1024,
                     num_fillers=4, num_evictors=2, read_ahead=2)
    region = umap(store, config=cfg)

    # 3. demand paging: read anywhere; the pager faults pages in
    view = region.view(np.int64)
    assert view[12345] == 12345
    assert list(view[1_000_000:1_000_004]) == [1_000_000, 1_000_001,
                                               1_000_002, 1_000_003]

    # 4. app-driven prefetch of an arbitrary page set (paper §3.6)
    region.prefetch_pages([3, 99, 7, 150])

    # 5. writes mark pages dirty; the watermark monitor flushes in background
    view[0:4] = np.array([9, 8, 7, 6], np.int64)
    region.flush()
    check = np.fromfile(path, np.int64, count=4)
    assert list(check) == [9, 8, 7, 6]

    print("stats:", {k: v for k, v in region.stats().items()
                     if k != "per_filler_fills"})

    # 6. page-size advisor: napkin math the paper's central knob
    advisor = PageSizeAdvisor(
        StoreProfile.nvme(),
        WorkloadProfile(useful_bytes_per_access=8, locality_bytes=1 << 20))
    print("advised page size for sequential-ish NVMe workload:",
          advisor.recommend() // 1024, "KiB")

    uunmap(region)
    store.close()
    print("quickstart OK")


if __name__ == "__main__":
    main()
